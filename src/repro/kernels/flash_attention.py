"""Causal flash-attention Pallas kernel (prefill/training forward).

TPU-native tiling: q tile (Tq, hd) stays resident in VMEM while KV tiles
(Tk, hd) stream; the (Tq, Tk) score tile lives only in VMEM/VREGs.  Online
softmax carries (acc, m, l) in f32 scratch.  GQA is expressed through the
BlockSpec index maps: the kv-head grid index is q_head // q_per_kv, so KV
tiles are fetched once per q-head group without materializing the repeat.
Causality (and an optional sliding window) skips whole tiles via pl.when —
the skipped-tile fraction is what cuts the compute term in the roofline.

Layout: q (B, H, S, hd); k, v (B, KV, S, hd).  hd padded to 128 by ops.py.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_s, m_s, l_s,
                  *, tq, tk, n_ktiles, causal, window, scale):
    jq = pl.program_id(2)
    jk = pl.program_id(3)

    @pl.when(jk == 0)
    def _init():
        acc_s[...] = jnp.zeros_like(acc_s[...])
        m_s[...] = jnp.full_like(m_s[...], NEG)
        l_s[...] = jnp.zeros_like(l_s[...])

    q_start = jq * tq
    k_start = jk * tk
    # tile-level visibility: skip tiles fully outside the causal/window band
    run = jnp.asarray(True)
    if causal:
        run = k_start <= q_start + tq - 1
    if window:
        run = jnp.logical_and(run, k_start + tk - 1 > q_start - window)

    @pl.when(run)
    def _tile():
        q = q_ref[0, 0].astype(jnp.float32)            # (Tq, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (Tk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
        mask = jnp.ones((tq, tk), bool)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG)
        m_old = m_s[...]
        m_new = jnp.maximum(m_old, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_old - m_new)
        l_s[...] = l_s[...] * corr + jnp.sum(p, axis=-1)
        acc_s[...] = acc_s[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(jk == n_ktiles - 1)
    def _out():
        o_ref[0, 0] = (acc_s[...] / jnp.maximum(l_s[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    tq: int = 128, tk: int = 128,
                    interpret: "bool | None" = None):
    """q: (B, H, S, hd); k, v: (B, KV, S, hd) -> (B, H, S, hd).
    ``interpret`` resolves outside the jit boundary."""
    return _flash_attention(q, k, v, causal=causal, window=window, tq=tq,
                            tk=tk, interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("causal", "window", "tq", "tk",
                                             "interpret"))
def _flash_attention(q, k, v, *, causal, window, tq, tk, interpret):
    B, H, S, hd = q.shape
    KV = k.shape[1]
    qpk = H // KV
    tq = min(tq, S)
    tk = min(tk, S)
    assert S % tq == 0 and S % tk == 0, (S, tq, tk)
    n_ktiles = S // tk
    scale = 1.0 / math.sqrt(hd)
    kernel = functools.partial(_flash_kernel, tq=tq, tk=tk,
                               n_ktiles=n_ktiles, causal=causal,
                               window=window, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, S // tq, n_ktiles),
        in_specs=[
            pl.BlockSpec((1, 1, tq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, tk, hd),
                         lambda b, h, iq, ik, _qpk=qpk: (b, h // _qpk, ik, 0)),
            pl.BlockSpec((1, 1, tk, hd),
                         lambda b, h, iq, ik, _qpk=qpk: (b, h // _qpk, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, tq, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((tq, hd), jnp.float32),
                        pltpu.VMEM((tq,), jnp.float32),
                        pltpu.VMEM((tq,), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
    return out
