"""Staged cascade execution: the :class:`DecodeState` pytree and the
segment-skipping executor that makes early exit mean early *termination*.

The paper's claim is that inference stops as soon as the softmax confidence
clears the calibrated threshold — yet a batched TPU decode graph has a fixed
shape, so the seed implementation computed every segment and merely *selected*
the exit, leaving the measured speedup analytic (MACs), not wall-clock.  This
module closes that gap the way IDK Cascades (Wang et al., 2017) and Learning
to Cascade (Enomoto & Eda, 2021) frame it: the exit decision is part of the
execution program, not a post-hoc filter.

Two pieces:

* :class:`DecodeState` — the explicit, jit/shard-friendly pytree carried
  across decode steps: the cache-write cursor ``t``, the per-sequence
  ``active`` mask, the stateful-measure carry (patience streaks), an EMA of
  the answering confidence (per-slot difficulty telemetry, surfaced through
  the serving engine's stats), and per-segment execution counters.

* :class:`StagedExecutor` — runs the cascade one segment at a time, feeding
  each segment's exit logits to the shared
  :class:`~repro.core.policy.ExitDecider` scan (the fused exit-update Pallas
  kernel when ``cfg.use_kernels``).  Under ``cascade.exit_mode ==
  "cond_batch"`` every segment after the first sits under ``lax.cond``: once
  all live sequences have exited, deeper segments take only the cheap
  ``backfill`` path (cache coherence writes), skipping their matmuls
  entirely.  Under ``"select"`` the graph stays fixed (the dry-run /
  roofline shape) but applies the SAME masked state updates, so the two
  modes produce bit-identical tokens, exit indices, and carried state —
  ``exit_mode`` chooses an execution strategy, never a semantics.

Cohort-split execution (``cascade.n_cohorts > 1``) has two memory layouts,
picked by ``cascade.cohort_layout`` (bit-identical outputs, different
copies — see :meth:`StagedExecutor.decode_step`):

* ``"major"`` (default) — the cohort-major hot path.  Cohorts are
  contiguous equal batch ranges, so viewing the batch axis as
  ``(cohort, B/C)`` is a zero-copy reshape; the step's hidden state /
  decision carry / context / active mask split into per-cohort parts ONCE
  (not per segment), and every deep segment dispatches on the lane's exit
  state: all-exited → one whole-batch backfill, none-exited → one
  whole-batch dense segment, mixed → per-cohort ``lax.cond`` over
  cohort-major cache views.  The per-cohort slice/re-join machinery only
  runs when cohorts actually disagree.
* ``"copy"`` — the legacy layout: every segment re-slices the batch per
  cohort and re-concatenates hidden state, carry and the full segment
  cache, whatever the exit state.  Kept as the ablation baseline the
  layout benchmark (``benchmarks/bench_llm_cascade.py``) measures against.

The per-slot ``DecodeState.active`` mask also rides in the decode context
(``ctx["live"]``), where the exit-masked decode-attention kernel early-outs
dead slots' grid cells (``cfg.use_kernels``).

This replaces the old fixed ``(params, token, t, cache, extra)`` serve-step
signature: launch steps and the serving engine now thread
``(params, token, cache, state, extra)`` with ``state: DecodeState`` (see
``launch/steps.py`` for the migration shim-free builders and
``launch/shard_rules.decode_state_spec`` for its sharding).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.policy import ExitDecider, ExitDecision

# EMA decay for the per-slot answering-confidence telemetry carried in
# DecodeState (same decay as DepthCompactor's host-side depth prior).
CONF_EMA_DECAY = 0.8

# (requested n_cohorts, batch) pairs already warned about degrading.
_COHORT_WARNED = set()


def effective_cohorts(n_cohorts: int, batch: int, warn: bool = False) -> int:
    """Largest divisor of ``batch`` that is <= ``n_cohorts`` (>= 1).

    Cohort slices must be equal-size static ranges, so an indivisible batch
    degrades gracefully instead of erroring — the same policy the sharding
    rules apply to indivisible axes.  ``warn=True`` emits a one-time
    warning per (n_cohorts, batch) pair when the degradation actually
    triggers, because silently collapsing to fewer (or one) cohorts
    forfeits exactly the skip granularity ``n_cohorts`` was asked for —
    size lanes with :func:`repro.serving.batching.cohort_capacity` to avoid
    it.
    """
    want = max(1, min(int(n_cohorts), int(batch)))
    c = want
    while batch % c:
        c -= 1
    if warn and c != int(n_cohorts) and (n_cohorts, batch) not in _COHORT_WARNED:
        _COHORT_WARNED.add((n_cohorts, batch))
        warnings.warn(
            f"n_cohorts={n_cohorts} does not divide batch={batch}; "
            f"degrading to {c} cohort(s).  Round the lane capacity up to a "
            f"cohort multiple (repro.serving.batching.cohort_capacity) to "
            f"keep the requested skip granularity.", stacklevel=3)
    return c


def _slice_ctx(ctx, lo, hi):
    """Batch-slice a decode context: ``cross`` (B, T, d) and the per-slot
    exit mask ``live`` (B,) carry a batch dim; so do the paged-layout
    block tables (K, B, nblk) and the per-slot kpos ring (B, W).
    Everything else (dense lane-wide kpos, scalars, shared params) is
    batch-free and passes through."""
    out = ctx
    cross = ctx.get("cross")
    if cross is not None:
        out = {**out, "cross": cross[lo:hi]}
    live = ctx.get("live")
    if live is not None:
        out = {**out, "live": live[lo:hi]}
    bts = ctx.get("block_tables")
    if bts is not None:
        out = {**out, "block_tables": bts[:, lo:hi]}
    kpos = ctx.get("kpos")
    if kpos is not None and getattr(kpos, "ndim", 1) == 2:
        out = {**out, "kpos": kpos[lo:hi]}
    return out


@dataclasses.dataclass
class DecodeState:
    """Per-lane decode carry (a registered pytree).

    t             () int32   — decode position == cache-write cursor.
    active        (B,) bool  — sequences still generating; finished slots
                               neither block segment skipping nor update
                               EMAs, and their attention grid cells
                               early-out in the exit-masked decode kernel.
    policy        stateful-measure carry (e.g. patience streaks,
                               (n_components, B) int32) or None.
    ema_conf      (B,) f32   — EMA of the answering confidence per lane
                               slot (difficulty telemetry; the engine
                               reports it per lane in ``stats()``).
    segments_run  (n_components,) int32 — how many decode steps actually
                               computed each segment (physical compute: in
                               ``select`` mode every segment counts every
                               step; in ``cond_batch`` skipped segments
                               don't).  The real-skip evidence.
    tel           :class:`repro.autotune.telemetry.ExitTelemetry` counters
                               accumulated inside the decode program, or
                               None (autotune disabled — the default,
                               keeping the carry byte-identical to the
                               pre-autotune layout).
    thresholds    (n_components,) f32 live threshold vector, or None (use
                               the config's static thresholds).  As carry
                               DATA, a ThresholdController push is a plain
                               array swap — no retrace.
    block_tables  (n_components, B, W/block_size) int32 paged-cache block
                               tables (``cache_layout="paged"``), or None
                               (dense slab — the carry stays byte-identical
                               to the pre-paging layout).  Carry DATA: the
                               engine re-binding freed blocks between
                               chunks is a plain array swap — no retrace.
    """

    t: jnp.ndarray
    active: jnp.ndarray
    policy: Optional[jnp.ndarray]
    ema_conf: jnp.ndarray
    segments_run: jnp.ndarray
    tel: Optional[object] = None
    thresholds: Optional[jnp.ndarray] = None
    block_tables: Optional[jnp.ndarray] = None

    def replace(self, **kw) -> "DecodeState":
        return dataclasses.replace(self, **kw)


jax.tree_util.register_dataclass(
    DecodeState,
    data_fields=("t", "active", "policy", "ema_conf", "segments_run",
                 "tel", "thresholds", "block_tables"),
    meta_fields=())


def init_decode_state(decider: ExitDecider, batch: int, n_components: int,
                      t: int = 0, active=None, telemetry=None,
                      thresholds=None, block_tables=None) -> DecodeState:
    """Fresh decode carry for a lane of ``batch`` sequences."""
    return DecodeState(
        t=jnp.asarray(t, jnp.int32),
        active=(jnp.ones((batch,), bool) if active is None
                else jnp.asarray(active, bool)),
        policy=decider.measure.init_state(n_components, batch),
        ema_conf=jnp.zeros((batch,), jnp.float32),
        segments_run=jnp.zeros((n_components,), jnp.int32),
        tel=telemetry,
        thresholds=(None if thresholds is None
                    else jnp.asarray(thresholds, jnp.float32)),
        block_tables=(None if block_tables is None
                      else jnp.asarray(block_tables, jnp.int32)))


class StagedExecutor:
    """Segment-at-a-time cascade decode under one :class:`ExitDecider`.

    ``decode_step`` is THE decode program; ``cfg.cascade.exit_mode`` only
    picks how it is realized:

    * ``"select"`` — fixed graph: every segment computes, the skip
      predicate selects between the full result and the backfill result.
      Lowered by the dry-run (roofline shape).
    * ``"cond_batch"`` — ``lax.cond`` per segment: when every live sequence
      has exited, the deep segment's matmuls do not execute; only the cheap
      cache backfill runs.  Wall-clock savings, identical outputs.

    Works for every registered measure/policy whose decision reduces to
    per-component gates over static thresholds — including stateful
    patience@k (streaks ride in ``DecodeState.policy``) and a *fitted*
    BudgetPolicy (its thresholds resolve to static floats at trace time).
    """

    def __init__(self, model, cfg=None, decider: Optional[ExitDecider] = None):
        self.model = model
        self.cfg = cfg or model.cfg
        self.decider = decider or ExitDecider.from_config(self.cfg)
        self.mode = self.cfg.cascade.exit_mode
        self.layout = self.cfg.cascade.cohort_layout
        self.n_components = self.cfg.cascade.n_components
        # per-segment megakernel route (rmsnorm + unembed matmul + exit
        # update in one pallas_call) — requires the fused-scan decider;
        # heads the fusion can't express fall back per segment inside
        # _scan_exit
        kt = getattr(self.cfg, "kernel_tune", None)
        self.use_megakernel = bool(kt and kt.megakernel
                                   and self.decider.fused_scan)
        self.use_cohort_scatter = bool(kt and kt.cohort_scatter)

    # sentinel: init_state should build fresh telemetry itself
    _AUTO_TELEMETRY = object()

    # ------------------------------------------------------------------
    def init_state(self, batch: int, t: int = 0, active=None,
                   mac_weights=None,
                   telemetry=_AUTO_TELEMETRY,
                   block_tables=None) -> DecodeState:
        """Fresh carry.  With ``cfg.autotune.enabled`` the state also gets
        zeroed telemetry counters (``mac_weights`` prices exits for the MAC
        counter — the engine passes its cache-length-aware prefix) and a
        live threshold vector seeded from the config.  Pass ``telemetry=``
        to carry existing counters into the fresh state (lane re-prefill)
        instead of allocating zeroed ones that would be thrown away.
        ``block_tables`` (paged cache layout) ride the carry as data."""
        tel = thresholds = None
        if self.cfg.autotune.enabled:
            if telemetry is self._AUTO_TELEMETRY:
                from repro.autotune.telemetry import telemetry_for
                tel = telemetry_for(self.cfg, mac_weights)
            else:
                tel = telemetry
            thresholds = self.cfg.cascade.thresholds
        return init_decode_state(self.decider, batch, self.n_components,
                                 t=t, active=active, telemetry=tel,
                                 thresholds=thresholds,
                                 block_tables=block_tables)

    def _carry_forward(self, state: DecodeState,
                       decision: ExitDecision) -> DecodeState:
        conf = decision.confidence.astype(jnp.float32)
        ema = jnp.where(state.active,
                        CONF_EMA_DECAY * state.ema_conf
                        + (1.0 - CONF_EMA_DECAY) * conf,
                        state.ema_conf)
        return state.replace(policy=decision.state, ema_conf=ema)

    # ------------------------------------------------------------------
    def prefill(self, params, tokens, cache, extra=None,
                state: Optional[DecodeState] = None):
        """Full-sequence prefill; returns (decision, cache, state) with the
        prefill decision seeding the stateful-measure carry (it counts as
        the streak's first step) and ``t`` set past the prompt.

        With telemetry enabled, the prefill decision contributes a free
        SHADOW observation per live slot: prefill computes every component
        anyway, so the decision carry's rider rows hold the full per-
        component confidence/prediction vectors at zero extra compute.
        """
        if state is None:
            state = self.init_state(tokens.shape[0])
        logits, cache = self.model.prefill(params, tokens, cache, extra,
                                           block_tables=state.block_tables)
        decision, carry = self.decider.decide_with_carry(
            logits, thresholds=state.thresholds, state=state.policy,
            active=state.active)
        if state.tel is not None:
            from repro.autotune.telemetry import accumulate_prefill
            state = state.replace(tel=accumulate_prefill(
                state.tel, carry["tcode"], state.active))
        state = self._carry_forward(state, decision).replace(
            t=jnp.asarray(tokens.shape[1], jnp.int32))
        return decision, cache, state

    # ------------------------------------------------------------------
    def _scan_exit(self, si, params, h, ths, sc=None, state=None, live=None):
        """Measure segment ``si``'s exit from its hidden state ``h``
        ((B, 1, d)) and fold it into the decision scan — THE exit-head
        call every decode path routes through.

        With ``cfg.kernel_tune.megakernel`` and a fused-scan decider this
        takes the per-segment megakernel (:meth:`ExitDecider.scan_hidden`):
        the (B, V) exit logits never materialize and the per-slot ``live``
        mask early-outs dead batch blocks before the unembed matmul.  Heads
        the fusion can't express (enhancement MLP, layernorm bias — see
        :meth:`~repro.models.model.CascadeModel.exit_head_params`) and
        non-fused deciders fall back to ``exit_logits`` +
        :meth:`ExitDecider.scan_logits`, unchanged semantics.
        """
        decider, model = self.decider, self.model
        if self.use_megakernel:
            hp = model.exit_head_params(params, si)
            if hp is not None:
                return decider.scan_hidden(
                    si, self.n_components, h[:, 0, :], hp[0], hp[1], ths,
                    carry=sc, state=state, live=live,
                    eps=self.cfg.norm_eps)
        lg = model.exit_logits(params, si, h)[:, 0, :]
        return decider.scan_logits(si, self.n_components, lg, ths, sc,
                                   state=state)

    # ------------------------------------------------------------------
    def _segment_paths(self, si, ctx_c, params, ths):
        """(run, skip) closures for one deeper segment over one cohort's
        (h, seg_cache, carry) triple — the two ``lax.cond`` branches.

        ``run`` computes the segment, measures its exit logits and folds
        them into the decision scan (:meth:`ExitDecider.scan_logits` — the
        fused exit-update kernel when enabled); ``skip`` only backfills the
        segment's caches from the exit hidden state.

        The DecodeState confidence EMA is deliberately NOT folded inside
        these branches: the fold is a mul+add chain XLA may contract into
        FMAs differently per surrounding computation, so folding in-branch
        puts ``select`` and ``cond_batch`` one ulp apart.  The executor
        folds once at the step boundary instead (:meth:`_carry_forward`),
        identically placed in every execution variant.  (The fused kernel
        still supports the in-kernel fold — ``ema_decay`` in
        :func:`repro.kernels.exit_update.exit_update` — for fixed-graph
        callers without a cross-branch bit-identity contract.)
        """
        model, decider, n_m = self.model, self.decider, self.n_components

        def run(h, seg_cache, sc):
            h2, nc2, _ = model.run_segment(si, params, h, ctx_c, seg_cache)
            return h2, nc2, self._scan_exit(si, params, h2, ths, sc,
                                            live=ctx_c.get("live"))

        def skip(h, seg_cache, sc):
            if self.cfg.cascade.state_backfill:
                seg_cache = model.backfill_segment(si, params, h, ctx_c,
                                                   seg_cache)
            return h, seg_cache, sc

        return run, skip

    def _segment_step(self, si, ctx_c, params, ths, h, seg_cache, sc,
                      active, shadow=False, hs=None):
        """One (segment, cohort) cell: cond-skip in ``cond_batch`` mode,
        compute-and-mask in ``select`` mode.  Returns
        (h, new_seg_cache, carry, ran, hs) with ``ran`` the 0/1 execution
        count feeding ``DecodeState.segments_run``.

        ``shadow`` / ``hs`` are the telemetry shadow pass (python False /
        None when telemetry is off — those graphs stay byte-identical to
        the pre-autotune program).  On a shadow step, segments the skip
        predicate would drop are OBSERVED, never committed: the shadow
        hidden chain ``hs`` (== the committed ``h`` until the first skip,
        since the skip predicate is monotone within a step) threads the
        true full-depth activations through the skipped suffix, each
        skipped segment computes its exit logits from it and lands ONLY
        the telemetry rider row — the committed hidden state, the
        backfilled caches, the decision carry and the patience streaks
        all keep exact skip semantics, so telemetry-on token streams are
        bit-identical to telemetry-off (pinned by tests/test_autotune.py).
        """
        run, skip_fn = self._segment_paths(si, ctx_c, params, ths)
        skip = self.decider.should_skip(sc, active)
        if shadow is False:
            if self.mode == "cond_batch":
                h, nc, sc = lax.cond(skip, skip_fn, run, h, seg_cache, sc)
                return (h, nc, sc,
                        jnp.logical_not(skip).astype(jnp.int32), hs)
            full = run(h, seg_cache, sc)
            lite = skip_fn(h, seg_cache, sc)
            h, nc, sc = jax.tree_util.tree_map(
                lambda a, b: jnp.where(skip, a, b), lite, full)
            return h, nc, sc, jnp.asarray(1, jnp.int32), hs
        model, decider, n_m = self.model, self.decider, self.n_components

        def run4(h, seg_cache, sc, hs):
            h2, nc2, sc2 = run(h, seg_cache, sc)
            return h2, nc2, sc2, h2          # shadow chain = real chain

        def observe4(h, seg_cache, sc, hs):
            # full-depth OBSERVATION: compute from the shadow chain, keep
            # only the telemetry rider row; commit the skip results
            h2s, _, _ = model.run_segment(si, params, hs, ctx_c, seg_cache)
            sc_obs = self._scan_exit(si, params, h2s, ths, sc,
                                     live=ctx_c.get("live"))
            sc = {**sc, "tcode": sc_obs["tcode"]}
            h, seg_cache, sc = skip_fn(h, seg_cache, sc)
            return h, seg_cache, sc, h2s

        def skip4(h, seg_cache, sc, hs):
            h, seg_cache, sc = skip_fn(h, seg_cache, sc)
            return h, seg_cache, sc, hs

        if self.mode == "cond_batch":
            def skip_branch(h, c, s, hs):
                return lax.cond(shadow, observe4, skip4, h, c, s, hs)
            h, nc, sc, hs = lax.cond(skip, skip_branch, run4,
                                     h, seg_cache, sc, hs)
            ran = jnp.logical_or(jnp.logical_not(skip),
                                 shadow).astype(jnp.int32)
            return h, nc, sc, ran, hs
        # select: ONE dense run, from the shadow chain (hs == h while any
        # sample is still undecided, so the skip-masked decision merge is
        # unchanged); shadow steps take the rider row from the computed
        # observation even where skip holds
        full = run(hs, seg_cache, sc)
        lite = skip_fn(h, seg_cache, sc)
        h, nc, sc_sel = jax.tree_util.tree_map(
            lambda a, b: jnp.where(skip, a, b), lite, full)
        observed = jnp.logical_or(jnp.logical_not(skip), shadow)
        sc_sel = {**sc_sel, "tcode": jnp.where(observed, full[2]["tcode"],
                                               lite[2]["tcode"])}
        return h, nc, sc_sel, jnp.asarray(1, jnp.int32), full[0]

    # ------------------------------------------------------------------
    def decode_step(self, params, token, cache, state: DecodeState,
                    extra=None):
        """One staged decode step.  token: (B, 1) int32.

        Returns (decision, new_cache, new_state).  Segment 0 always runs;
        each deeper segment runs only while some live sequence has not
        exited (cond_batch) or computes-but-masks (select).

        ``cfg.cascade.n_cohorts > 1`` splits the batch into C contiguous
        equal-size cohorts, each with its OWN skip predicate: a deep
        segment's compute is skipped for a cohort as soon as every live
        sequence in THAT cohort has exited, even while another cohort still
        needs it (nested ``lax.cond`` per cohort).  The serving engine
        places similar-depth requests into the same cohort so this converts
        more of the measured skip opportunity into realized skips.
        ``segments_run`` counts in cohort units: segment ``si`` advances by
        the number of cohorts that actually computed it (C per step when
        nothing skips; C == 1 reproduces the whole-batch predicate exactly).

        ``cfg.cascade.cohort_layout`` picks the memory layout of the
        cohort split (outputs bit-identical):

        * ``"major"`` — hot path: h / carry / context / active split per
          cohort ONCE, segment caches viewed cohort-major
          (``(n, C, B/C, ...)`` — a zero-copy reshape, cohorts being
          contiguous), and each deep segment dispatches on the exit state
          (all-exited / none-exited / mixed) so the per-cohort slice +
          re-join machinery only runs when cohorts actually disagree.
        * ``"copy"`` — the legacy per-segment slice + concat regardless of
          exit state (ablation baseline; this is the copy overhead the
          ROADMAP flagged).
        """
        model, decider, n_m = self.model, self.decider, self.n_components
        # live thresholds (autotune: carry data, a push never retraces)
        # win over the config's static vector
        if state.thresholds is not None:
            ths = decider.resolved_thresholds(n_m, state.thresholds)
        else:
            ths = decider.resolved_thresholds(n_m)
        t = state.t
        # telemetry shadow schedule: every shadow_every-th step (by the
        # position cursor — deterministic and identical across host/device
        # runtimes) OBSERVES the full depth: skipped segments compute their
        # exit logits from the shadow hidden chain for the telemetry rider
        # only, while caches/decisions/streaks keep exact skip semantics
        # (see _segment_step) — token streams never change.  Python False
        # when telemetry is off: the graphs stay untouched.
        shadow = False
        if state.tel is not None:
            shadow = jnp.equal(
                jnp.mod(t, jnp.int32(self.cfg.autotune.shadow_every)), 0)
        B = token.shape[0]
        C = effective_cohorts(self.cfg.cascade.n_cohorts, B, warn=True)
        Bc = B // C
        h, ctx = model.begin_decode(params, token, t, cache, extra)
        # thread the exit mask to the kernels: dead slots' attention grid
        # cells early-out (zero rows) — safe, because a retired slot's
        # outputs are never read and its lane re-prefills before reuse
        ctx = {**ctx, "live": state.active}
        # paged layout: block tables ride the carry as data; the model
        # injects per-segment rows into each segment's attention ctx
        paged = state.block_tables is not None
        if paged:
            ctx = {**ctx, "block_tables": state.block_tables}
        segs = cache["segments"]
        new_segs = []
        ran = [jnp.asarray(C, jnp.int32)]

        # segment 0 computes for everyone (every cohort needs it)
        h, nc, _ = model.run_segment(0, params, h, ctx, segs[0])
        new_segs.append(nc)
        sc = self._scan_exit(0, params, h, ths, state=state.policy,
                             live=state.active)
        # the telemetry shadow chain starts at the committed hidden state
        # (segment 0 always computes); None keeps telemetry-off graphs
        # byte-identical to the pre-autotune program
        hs = h if shadow is not False else None

        if C == 1:
            for si in range(1, n_m):
                h, nc, sc, r, hs = self._segment_step(
                    si, ctx, params, ths, h, segs[si], sc, state.active,
                    shadow=shadow, hs=hs)
                new_segs.append(nc)
                ran.append(r)
        elif self.layout == "copy":
            # ablation baseline: re-slice + re-concat per segment.  Paged
            # stores have no batch dim to slice — each cohort addresses the
            # SHARED store through its own table rows (sliced via ctx), so
            # the store CHAINS through the cohorts (disjoint writes) and
            # the re-concat disappears.
            for si in range(1, n_m):
                h_parts, nc_parts, sc_parts = [], [], []
                hs_parts = [] if hs is not None else None
                ran_si = jnp.zeros((), jnp.int32)
                seg_cur = segs[si]
                for c in range(C):
                    lo, hi = c * Bc, (c + 1) * Bc
                    seg_c = seg_cur if paged else jax.tree_util.tree_map(
                        lambda x: x[:, lo:hi], segs[si])
                    h_c, nc_c, sc_c, r, hs_c = self._segment_step(
                        si, _slice_ctx(ctx, lo, hi), params, ths,
                        h[lo:hi], seg_c, decider.slice_carry(sc, lo, hi),
                        state.active[lo:hi], shadow=shadow,
                        hs=None if hs is None else hs[lo:hi])
                    ran_si = ran_si + r
                    h_parts.append(h_c)
                    if paged:
                        seg_cur = nc_c
                    else:
                        nc_parts.append(nc_c)
                    sc_parts.append(sc_c)
                    if hs_parts is not None:
                        hs_parts.append(hs_c)
                h = jnp.concatenate(h_parts, axis=0)
                if hs_parts is not None:
                    hs = jnp.concatenate(hs_parts, axis=0)
                nc = seg_cur if paged else jax.tree_util.tree_map(
                    lambda *xs: jnp.concatenate(xs, axis=1), *nc_parts)
                sc = decider.concat_carry(sc_parts)
                ran.append(ran_si)
                new_segs.append(nc)
        else:
            # cohort-major hot path: h / decision carry / context / active
            # are split ONCE into per-cohort parts (zero-copy views —
            # cohorts are contiguous batch ranges) that persist across the
            # deep segments; each segment then DISPATCHES on the lane's
            # exit state instead of always paying the per-cohort machinery:
            #
            #   all exited  -> ONE whole-batch backfill: no cache slicing,
            #                  no per-cohort conds, no re-join — the common
            #                  state at low thresholds, i.e. exactly where
            #                  the paper's savings materialize;
            #   none exited -> ONE whole-batch dense segment: full-width
            #                  matmuls, again no cohort machinery — the
            #                  dense ceiling costs what C == 1 costs;
            #   mixed       -> per-cohort lax.cond over cohort-major cache
            #                  views, results re-joined per segment.
            #
            # The three branches are bit-identical per row because every
            # decode op is batch-separable (pinned by the layout parity
            # tests).  MoE couples rows through expert capacity, so MoE
            # configs keep a two-way (all-exited vs per-cohort) dispatch.
            spans = [(c * Bc, (c + 1) * Bc) for c in range(C)]
            h_parts = [h[lo:hi] for lo, hi in spans]
            hs_parts = ([p for p in h_parts] if hs is not None else None)
            sc_parts = [decider.slice_carry(sc, lo, hi) for lo, hi in spans]
            ctx_parts = [_slice_ctx(ctx, lo, hi) for lo, hi in spans]
            act_parts = [state.active[lo:hi] for lo, hi in spans]
            separable = self.cfg.n_experts == 0

            for si in range(1, n_m):
                preds = jnp.stack([decider.should_skip(s, a)
                                   for s, a in zip(sc_parts, act_parts)])

                def _all_skip(hp, seg, scp, hsp, _si=si):
                    if self.cfg.cascade.state_backfill:
                        seg = model.backfill_segment(
                            _si, params, jnp.concatenate(hp, axis=0), ctx,
                            seg)
                    return (list(hp), seg, list(scp),
                            jnp.zeros((), jnp.int32), hsp)

                def _mixed(hp, seg, scp, hsp, _si=si):
                    # dense: zero-copy cohort-major view of the slab.
                    # paged: no batch dim to view — the SHARED store chains
                    # through the cohorts, each addressing it through its
                    # own table rows (ctx_parts carry the sliced tables).
                    # the dense re-join is either the legacy concat or, with
                    # cfg.kernel_tune.cohort_scatter, C aliased partial
                    # writes into the input slab (bit-identical; PR 4
                    # documented XLA does not elide the concat's full-slab
                    # materialization inside while+cond)
                    scatter = self.use_cohort_scatter and not paged
                    if scatter:
                        from repro.kernels.ops import cohort_scatter_tree
                        scat = seg
                    if not paged:
                        view = jax.tree_util.tree_map(
                            lambda x: x.reshape((x.shape[0], C, Bc)
                                                + x.shape[2:]), seg)
                    hp, scp = list(hp), list(scp)
                    hsp = None if hsp is None else list(hsp)
                    parts = []
                    r = jnp.zeros((), jnp.int32)
                    for c in range(C):
                        seg_c = seg if paged else jax.tree_util.tree_map(
                            lambda x: x[:, c], view)
                        hp[c], nc_c, scp[c], rc, hs_c = self._segment_step(
                            _si, ctx_parts[c], params, ths, hp[c], seg_c,
                            scp[c], act_parts[c], shadow=shadow,
                            hs=None if hsp is None else hsp[c])
                        if hsp is not None:
                            hsp[c] = hs_c
                        if paged:
                            seg = nc_c
                        elif scatter:
                            scat = cohort_scatter_tree(
                                scat, nc_c, c, C,
                                interpret=self.cfg.kernel_interpret)
                        else:
                            parts.append(nc_c)
                        r = r + rc
                    if paged:
                        nc = seg
                    elif scatter:
                        nc = scat
                    else:
                        nc = jax.tree_util.tree_map(
                            lambda *xs: jnp.concatenate(xs, axis=1), *parts)
                    return hp, nc, scp, r, hsp

                def _all_run(hp, seg, scp, hsp, _si=si):
                    h2, nc, _ = model.run_segment(
                        _si, params, jnp.concatenate(hp, axis=0), ctx, seg)
                    sc2 = self._scan_exit(_si, params, h2, ths,
                                          decider.concat_carry(list(scp)),
                                          live=ctx["live"])
                    out_parts = [h2[lo:hi] for lo, hi in spans]
                    return (out_parts, nc,
                            [decider.slice_carry(sc2, lo, hi)
                             for lo, hi in spans],
                            jnp.asarray(C, jnp.int32),
                            (None if hsp is None else list(out_parts)))

                if self.mode != "cond_batch":
                    # select: fixed graph — the dry-run / roofline shape
                    h_parts, nc, sc_parts, r, hs_parts = _mixed(
                        h_parts, segs[si], sc_parts, hs_parts)
                elif separable:
                    n_skip = jnp.sum(preds.astype(jnp.int32))
                    idx = jnp.where(n_skip == C, 0,
                                    jnp.where(n_skip == 0, 2, 1))
                    if shadow is not False:
                        # telemetry shadow step: any skipped cohort must
                        # be OBSERVED, which only the per-cohort dispatch
                        # does (skip semantics + rider-only observation in
                        # _segment_step); the none-skipped dense branch
                        # already observes everything
                        idx = jnp.where(
                            jnp.logical_and(shadow, n_skip > 0), 1, idx)
                    h_parts, nc, sc_parts, r, hs_parts = lax.switch(
                        idx, (_all_skip, _mixed, _all_run), h_parts,
                        segs[si], sc_parts, hs_parts)
                else:
                    all_skip = jnp.all(preds)
                    if shadow is not False:
                        # shadow steps observe skipped cohorts per cohort
                        all_skip = jnp.logical_and(
                            all_skip, jnp.logical_not(shadow))
                    h_parts, nc, sc_parts, r, hs_parts = lax.cond(
                        all_skip, _all_skip, _mixed, h_parts,
                        segs[si], sc_parts, hs_parts)
                new_segs.append(nc)
                ran.append(r)
            sc = decider.concat_carry(sc_parts)

        decision = decider.finish_scan(sc)
        cache = model.commit_decode(cache, new_segs, t)
        if state.tel is not None:
            from repro.autotune.telemetry import accumulate_decode
            state = state.replace(tel=accumulate_decode(
                state.tel, sc, decision, state.active, shadow))
        state = self._carry_forward(state, decision).replace(
            t=t + 1, segments_run=state.segments_run + jnp.stack(ran))
        return decision, cache, state
