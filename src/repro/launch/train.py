"""Training launcher.

On real hardware this runs under the production mesh; on this CPU container
it runs reduced configs on a 1x1 mesh (--smoke) — the same code path,
sharding rules, and step function either way.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
        --steps 50 --batch 4 --seq 64
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.ckpt import save_checkpoint
from repro.configs import INPUT_SHAPES, get_config, reduced
from repro.data.lm_pipeline import SyntheticLMStream
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.shard_rules import batch_spec, param_spec, to_shardings
from repro.launch.steps import make_optimizer, make_train_step
from repro.models.model import build_model, extra_input_shapes
from repro.utils import get_logger, tree_size

log = get_logger("train")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on the host mesh (CPU)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    log.info("arch=%s params=%s", cfg.name, f"{tree_size(params):,}")
    opt = make_optimizer(cfg)
    opt_state = opt.init(params)

    p_shard = to_shardings(mesh, param_spec(params, cfg, mesh))
    params = jax.device_put(params, p_shard)
    opt_state = jax.device_put(opt_state,
                               to_shardings(mesh, param_spec(opt_state, cfg,
                                                             mesh)))
    step_fn = jax.jit(make_train_step(model, cfg, opt))

    stream = SyntheticLMStream(cfg.vocab_size, args.seq, args.batch)
    extras = {k: jnp.zeros(v, jnp.float32)
              for k, v in extra_input_shapes(cfg, args.batch).items()}
    losses = []
    with mesh:
        t0 = time.time()
        for step, (toks, labels) in zip(range(args.steps), stream):
            batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
            if extras:
                batch["extra"] = extras
            params, opt_state, loss = step_fn(params, opt_state,
                                              jnp.asarray(step), batch)
            losses.append(float(loss))
            if step % args.log_every == 0:
                log.info("step %d loss %.4f", step, losses[-1])
        dt = time.time() - t0
    log.info("done: %d steps in %.1fs; loss %.4f -> %.4f", args.steps, dt,
             losses[0], losses[-1])
    if args.ckpt_dir:
        path = save_checkpoint(args.ckpt_dir, args.steps, params)
        log.info("checkpoint: %s", path)
    assert np.isfinite(losses).all(), "non-finite loss"
    if args.steps >= 6:  # trend check (per-batch noise dominates tiny runs)
        k = max(2, args.steps // 3)
        assert np.mean(losses[-k:]) < np.mean(losses[:k]), \
            "loss did not trend down"


if __name__ == "__main__":
    main()
