"""Kernel microbenchmarks: the autotuner sweep as a bench.

For every Pallas kernel (decode attention, flash attention, rmsnorm,
confidence, exit update, the per-segment megakernel, paged gather) the
sweep times the DEFAULT tile configuration against every candidate and
reports one row per (kernel, shape): default µs, tuned µs, and
``tuned_speedup`` — which is >= 1.0 BY CONSTRUCTION because the default is
itself a candidate and both timings come from the same sweep
(``check_bench_serving.py`` gates exactly this invariant, per shape).

Every row carries execution-backend provenance (``interpret`` vs
``compiled``, plus the jax platform): on CPU CI the kernels run through the
Pallas interpreter, where absolute times mean nothing and relative tile
times mean little — those rows are labeled and treated as advisory; only
compiled rows are performance evidence.

``run()`` also sets ``LAST_KERNELS_SUMMARY`` for ``benchmarks/run.py`` to
merge into ``BENCH_serving.json["kernels"]``.
"""
from repro.kernels import autotune

# set by run(): machine-readable per-kernel microbench summary
LAST_KERNELS_SUMMARY = None


def run(quick: bool = False):
    global LAST_KERNELS_SUMMARY
    shapes = "tiny" if quick else "serving"
    winners, bench_rows = autotune.sweep(shapes=shapes,
                                         reps=2 if quick else 3)
    rows = []
    for r in bench_rows:
        tiles = ";".join(f"{k}={v}" for k, v in sorted(r["tiles"].items()))
        rows.append((
            f"kernels/{r['kernel']}/{r['shape']}",
            r["tuned_us"],
            f"default_us={r['default_us']};speedup={r['tuned_speedup']};"
            f"tiles={tiles};backend={r['backend']}"))
    LAST_KERNELS_SUMMARY = {
        "shapes": shapes,
        "backend": bench_rows[0]["backend"] if bench_rows else None,
        "platform": bench_rows[0]["platform"] if bench_rows else None,
        "tuned_tiles": winners,
        "default_tiles": {k: dict(v)
                          for k, v in autotune.DEFAULT_TILES.items()},
        "rows": bench_rows,
    }
    return rows
