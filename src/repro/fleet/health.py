"""Per-member health tracking for the fleet scheduler.

A member's heartbeat is its ``stats()`` call — if the probe (or a
``step()``) raises, that is a failure.  Consecutive failures back off
exponentially (``backoff_base ** failures`` ticks, capped at
``backoff_cap``) before the next probe is even attempted, so a crashing
member is not hammered every tick; at ``max_failures`` consecutive
failures the member is marked unhealthy and the scheduler stops placing
on (and stepping) it.  One successful probe fully recovers it — the
failure counter and backoff reset, because a member that answers a probe
is a member whose host process is alive, whatever its history.

All of this is plain host bookkeeping: no device state, no threads.  The
scheduler drives :meth:`EngineHealth.beat` from its own tick counter.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

from repro.utils import get_logger

log = get_logger("fleet")


@dataclasses.dataclass
class HealthState:
    """One member's view: counters plus the backoff window."""

    failures: int = 0            # consecutive (resets on success)
    total_failures: int = 0      # lifetime
    beats: int = 0               # successful probes
    backoff: int = 0             # current backoff window (ticks)
    next_probe_tick: int = 0     # no probe before this scheduler tick
    healthy: bool = True
    unhealthy_marks: int = 0     # times the member crossed max_failures
    last_error: Optional[str] = None


class EngineHealth:
    """Failure counting + bounded exponential backoff over N members."""

    def __init__(self, n_members: int, *, max_failures: int = 3,
                 backoff_base: int = 2, backoff_cap: int = 64):
        self.max_failures = max_failures
        self.backoff_base = backoff_base
        self.backoff_cap = backoff_cap
        self.states: List[HealthState] = [HealthState()
                                          for _ in range(n_members)]

    def add_member(self) -> None:
        self.states.append(HealthState())

    def healthy(self, idx: int) -> bool:
        return self.states[idx].healthy

    def note_failure(self, idx: int, tick: int,
                     err: Optional[BaseException] = None) -> None:
        """Record one failed probe/step; arms the backoff window and marks
        the member unhealthy at ``max_failures`` consecutive failures."""
        st = self.states[idx]
        st.failures += 1
        st.total_failures += 1
        st.last_error = repr(err) if err is not None else None
        st.backoff = min(self.backoff_cap,
                         self.backoff_base ** st.failures)
        st.next_probe_tick = tick + st.backoff
        if st.healthy and st.failures >= self.max_failures:
            st.healthy = False
            st.unhealthy_marks += 1
            log.warning("member %d unhealthy after %d consecutive failures "
                        "(last: %s)", idx, st.failures, st.last_error)

    def beat(self, idx: int, tick: int,
             probe: Callable[[], object]) -> Optional[bool]:
        """Probe member ``idx`` by calling ``probe()`` (typically the
        member's ``stats``).  Returns True on success, False on failure,
        None when the member is inside its backoff window (no probe
        attempted — backoff is what keeps a crashing member from being
        hammered every heartbeat)."""
        st = self.states[idx]
        if tick < st.next_probe_tick:
            return None
        try:
            probe()
        except Exception as e:                        # noqa: BLE001
            self.note_failure(idx, tick, e)
            return False
        st.beats += 1
        if not st.healthy:
            log.info("member %d recovered after %d consecutive failures",
                     idx, st.failures)
        st.failures = 0
        st.backoff = 0
        st.next_probe_tick = tick
        st.healthy = True
        return True

    def stats(self) -> List[dict]:
        return [dataclasses.asdict(st) for st in self.states]

    def summary(self, idx: int) -> dict:
        """One member's health in the shape the fleet surfaces per-member
        (stats()["members"][idx] and the Prometheus scrape): a flapping
        member is visible as nonzero consecutive failures / backoff
        without reading logs."""
        st = self.states[idx]
        return {
            "healthy": st.healthy,
            "consecutive_failures": st.failures,
            "total_failures": st.total_failures,
            "backoff": st.backoff,
            "next_probe_tick": st.next_probe_tick,
            "unhealthy_marks": st.unhealthy_marks,
            "last_error": st.last_error,
        }
