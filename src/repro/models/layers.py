"""Core transformer layers: norms, RoPE, GQA/SWA attention (full / chunked /
decode / cross), and MLPs.

Attention has three execution paths:

* ``attend_full`` — plain einsum softmax; used for short sequences.
* ``attend_chunked`` — online-softmax ``lax.scan`` over KV chunks; memory is
  O(S·chunk) instead of O(S²), which is what lets the 32k-prefill shape
  *compile within HBM* on the 256-chip mesh.  This is the pure-XLA flash
  formulation; the Pallas kernel (kernels/flash_attention.py) is the fused
  VMEM-tiled version selected by ``cfg.use_kernels``.
* ``attend_decode`` — single-query attention against a (possibly ring-buffer)
  KV cache.

All paths take a ``kpos`` vector giving the *absolute position* of each key
slot (-1 ⇒ empty slot) which uniformly encodes causal, sliding-window, and
ring-buffer masking:  key j visible to query at position t iff
``0 <= kpos[j] <= t`` and ``kpos[j] > t - window`` (when window > 0).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import nn

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, weight, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * lax.rsqrt(var + eps)).astype(dtype) * weight


def layernorm(x, weight, bias, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * lax.rsqrt(var + eps)
    return y.astype(dtype) * weight + bias


def norm_init(key, cfg, dim=None):
    d = dim or cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"w": jnp.ones((d,), jnp.float32)}
    return {"w": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def norm_apply(params, cfg, x):
    if "b" in params:
        return layernorm(x, params["w"].astype(x.dtype),
                         params["b"].astype(x.dtype), cfg.norm_eps)
    if cfg.use_kernels:
        from repro.kernels.ops import rmsnorm_fused
        return rmsnorm_fused(x, params["w"], eps=cfg.norm_eps,
                             interpret=cfg.kernel_interpret)
    return rmsnorm(x, params["w"].astype(x.dtype), cfg.norm_eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    if theta <= 0:
        return x  # learned absolute positions (whisper) — no RoPE
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                      # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention parameter init
# ---------------------------------------------------------------------------

def attn_init(key, cfg, *, cross: bool = False, d_model: int | None = None):
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim
    kq, kk, kv_, ko, kn, kn2 = nn.split_keys(key, 6)
    p = {
        "wq": nn.dense_init(kq, (d, cfg.n_heads * hd)),
        "wk": nn.dense_init(kk, (d, cfg.n_kv_heads * hd)),
        "wv": nn.dense_init(kv_, (d, cfg.n_kv_heads * hd)),
        "wo": nn.dense_init(ko, (cfg.n_heads * hd, d)),
        "norm": norm_init(kn, cfg, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
    if cross:
        # gated cross-attention (llama-3.2-vision style tanh gate)
        p["gate"] = jnp.zeros((), jnp.float32)
    return p


def qkv_project(params, cfg, x, *, rope_positions=None):
    """Project x -> (q, k, v) with head reshape and optional RoPE."""
    hd = cfg.resolved_head_dim
    q = x @ params["wq"].astype(x.dtype)
    k = x @ params["wk"].astype(x.dtype)
    v = x @ params["wv"].astype(x.dtype)
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    B, S = x.shape[0], x.shape[1]
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    if rope_positions is not None:
        q = apply_rope(q, rope_positions, cfg.rope_theta)
        k = apply_rope(k, rope_positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------

def _expand_kv(k, q_per_kv: int):
    """(B, S, kv, hd) -> (B, S, kv, qpk, hd) broadcast helper."""
    return jnp.repeat(k, q_per_kv, axis=2) if q_per_kv > 1 else k


def attend_full(q, k, v, qpos, kpos, window: int = 0, causal: bool = True):
    """Plain softmax attention.  q: (B,Sq,H,hd); k,v: (B,Sk,KV,hd).

    qpos: (Sq,) or (B,Sq); kpos: (Sk,) or (B,Sk) absolute positions, -1=empty.
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    qpk = H // KV
    qh = q.reshape(B, Sq, KV, qpk, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qh, k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(hd)
    mask = _mask(qpos, kpos, window, causal)           # (B?, Sq, Sk)
    scores = jnp.where(_bcast_mask(mask, scores.shape), scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, H, hd)


def _mask(qpos, kpos, window, causal):
    qp = jnp.atleast_2d(qpos)[..., :, None]            # (B?, Sq, 1)
    kp = jnp.atleast_2d(kpos)[..., None, :]            # (B?, 1, Sk)
    m = kp >= 0
    if causal:
        m = m & (kp <= qp)
    if window:
        m = m & (kp > qp - window)
    return m


def _bcast_mask(mask, score_shape):
    # mask (B?, Sq, Sk) -> (B, KV, qpk, Sq, Sk)
    B, KV, qpk, Sq, Sk = score_shape
    m = jnp.broadcast_to(mask, (B,) + mask.shape[-2:])
    return m[:, None, None, :, :]


def attend_chunked(q, k, v, qpos, kpos, window: int = 0, causal: bool = True,
                   chunk: int = 1024):
    """Online-softmax attention, scanning KV chunks (pure-XLA flash).

    Memory: O(B·H·Sq·chunk) transient scores instead of O(Sq·Sk).
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    if Sk % chunk != 0:
        pad = chunk - Sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos2 = jnp.atleast_2d(kpos)
        kpos = jnp.pad(kpos2, ((0, 0), (0, pad)), constant_values=-1)
        Sk += pad
    n_chunks = Sk // chunk
    qpk = H // KV
    qh = q.reshape(B, Sq, KV, qpk, hd)
    kc = k.reshape(B, n_chunks, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    kpos_b = jnp.broadcast_to(jnp.atleast_2d(kpos), (B, Sk))
    kpc = kpos_b.reshape(B, n_chunks, chunk).transpose(1, 0, 2)

    acc0 = jnp.zeros((B, Sq, KV, qpk, hd), jnp.float32)
    m0 = jnp.full((B, Sq, KV, qpk), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, qpk), jnp.float32)

    def body(carry, xs):
        acc, m, l = carry
        kj, vj, kpj = xs
        s = jnp.einsum("bqkgh,bskh->bqkgs", qh, kj,
                       preferred_element_type=jnp.float32) / math.sqrt(hd)
        msk = _mask(qpos, kpj, window, causal)          # (B, Sq, chunk)
        s = jnp.where(msk[:, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bqkgs,bskh->bqkgh", p.astype(vj.dtype), vj).astype(jnp.float32)
        return (acc, m_new, l), None

    (acc, m, l), _ = lax.scan(body, (acc0, m0, l0), (kc, vc, kpc))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def attend_decode(q, k_cache, v_cache, t, kpos, window: int = 0):
    """Single-token attention.  q: (B,1,H,hd); caches: (B,W,KV,hd);
    t: scalar or (B,) current absolute position; kpos: (W,) or (B,W)."""
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    qpk = H // KV
    # low-precision (e.g. f8) caches upcast at read — bandwidth is saved on
    # the HBM side, compute stays in the matmul dtype
    k_cache = k_cache.astype(q.dtype)
    v_cache = v_cache.astype(q.dtype)
    qh = q.reshape(B, KV, qpk, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qh, k_cache,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    tq = jnp.asarray(t)
    tq = tq[:, None] if tq.ndim == 1 else tq[None, None]     # (B,1) or (1,1)
    kp = jnp.atleast_2d(kpos)                                  # (B?, W)
    m = (kp >= 0) & (kp <= tq)
    if window:
        m = m & (kp > tq - window)
    m = jnp.broadcast_to(m, (B, k_cache.shape[1]))
    s = jnp.where(m[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p.astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, H, hd)


def attend_chunked_2d(q, k, v, qpos, kpos, window: int = 0,
                      causal: bool = True, qchunk: int = 512,
                      kchunk: int = 1024, causal_skip: bool = True):
    """Query-and-key chunked attention: ``lax.map`` over query chunks, each
    running an online-softmax loop over KV chunks.  Peak transient memory is
    O(B·H·qchunk·kchunk) — independent of S — which is what lets the 32k
    shapes fit per-device HBM at compile time.

    causal_skip (§Perf H4): the inner loop is a ``fori_loop`` whose bounds
    are derived from the query chunk's position range, so KV chunks entirely
    outside the causal/window band are never computed — halving prefill
    attention FLOPs vs the masked-only variant (and matching the Pallas
    kernel's pl.when tile skipping on real hardware)."""
    B, Sq, H, hd = q.shape
    if Sq % qchunk != 0:
        return attend_chunked(q, k, v, qpos, kpos, window, causal,
                              chunk=kchunk)
    Sk, KV = k.shape[1], k.shape[2]
    if Sk % kchunk != 0:
        pad = kchunk - Sk % kchunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(jnp.atleast_2d(kpos), ((0, 0), (0, pad)),
                       constant_values=-1)
        Sk += pad
    nq, nk = Sq // qchunk, Sk // kchunk
    qpk = H // KV
    qc = q.reshape(B, nq, qchunk, H, hd).swapaxes(0, 1)
    qp = jnp.broadcast_to(jnp.atleast_2d(qpos), (B, Sq))
    qpc = qp.reshape(B, nq, qchunk).swapaxes(0, 1)
    kc = k.reshape(B, nk, kchunk, KV, hd).swapaxes(0, 1)
    vc = v.reshape(B, nk, kchunk, KV, hd).swapaxes(0, 1)
    kpos_b = jnp.broadcast_to(jnp.atleast_2d(kpos), (B, Sk))
    kpc = kpos_b.reshape(B, nk, kchunk).swapaxes(0, 1)

    def per_q(args):
        qj, qpj = args                            # (B,qchunk,H,hd), (B,qchunk)
        qh = qj.reshape(B, qchunk, KV, qpk, hd)
        acc0 = jnp.zeros((B, qchunk, KV, qpk, hd), jnp.float32)
        m0 = jnp.full((B, qchunk, KV, qpk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, qchunk, KV, qpk), jnp.float32)

        if causal_skip and causal:
            # chunk index range actually visible from this query chunk
            hi = (jnp.max(qpj) // kchunk + 1).astype(jnp.int32)
            lo = ((jnp.maximum(jnp.min(qpj) - window + 1, 0) // kchunk)
                  .astype(jnp.int32) if window
                  else jnp.zeros((), jnp.int32))
        else:
            # python-int bounds => static trip count => reverse-mode AD works
            hi, lo = nk, 0

        def body(i, carry):
            acc, m, l = carry
            kj = lax.dynamic_index_in_dim(kc, i, 0, keepdims=False)
            vj = lax.dynamic_index_in_dim(vc, i, 0, keepdims=False)
            kpj = lax.dynamic_index_in_dim(kpc, i, 0, keepdims=False)
            s = jnp.einsum("bqkgh,bskh->bqkgs", qh, kj,
                           preferred_element_type=jnp.float32) \
                / math.sqrt(hd)
            msk = _mask(qpj, kpj, window, causal)
            s = jnp.where(msk[:, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bqkgs,bskh->bqkgh", p.astype(vj.dtype), vj
            ).astype(jnp.float32)
            return acc, m_new, l

        acc, m, l = lax.fori_loop(lo, hi, body, (acc0, m0, l0))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.reshape(B, qchunk, H, hd).astype(q.dtype)

    out = lax.map(per_q, (qc, qpc))              # (nq, B, qchunk, H, hd)
    return out.swapaxes(0, 1).reshape(B, Sq, H, hd)


def pick_attend(cfg, Sq, Sk, differentiable: bool = False):
    """Choose the attention path by sequence size (compile-memory driven).

    ``differentiable=True`` (training) avoids the dynamic-bound fori_loop of
    the causal-skip path — reverse-mode AD requires static trip counts."""
    if Sq >= 4096 and Sk >= 4096:
        return partial(attend_chunked_2d, causal_skip=not differentiable,
                       qchunk=cfg.attn_qchunk, kchunk=cfg.attn_kchunk)
    if Sk >= 2048:
        return partial(attend_chunked, chunk=cfg.attn_kchunk)
    return attend_full


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key, cfg, d_ff: int | None = None, d_model: int | None = None):
    d = d_model or cfg.d_model
    ff = d_ff or cfg.d_ff
    k1, k2, k3, kn = nn.split_keys(key, 4)
    p = {"w_up": nn.dense_init(k1, (d, ff)),
         "w_down": nn.dense_init(k2, (ff, d)),
         "norm": norm_init(kn, cfg, d)}
    if cfg.act == "swiglu":
        p["w_gate"] = nn.dense_init(k3, (d, ff))
    return p


def mlp_apply(params, cfg, x):
    up = x @ params["w_up"].astype(x.dtype)
    if "w_gate" in params:
        gate = x @ params["w_gate"].astype(x.dtype)
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    return h @ params["w_down"].astype(x.dtype)
