"""Depth-compacted continuous batching.

The TPU adaptation of the paper's per-sample early termination (DESIGN.md §5):
``cond_batch`` segment skipping only saves compute when *every* co-resident
sequence is confident, so the scheduler's job is to co-locate requests with
similar expected exit depth.  Each *lane* is an independent (cache, batch)
decode stream; requests are admitted to the lane whose running depth estimate
matches the request's predicted depth (from its prefill exit, then an EMA of
observed exits).

This is a pure-host scheduling layer: no device state moves between lanes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class LaneStats:
    depth_ema: float
    steps: int = 0
    skipped_segments: int = 0
    total_segments: int = 0


class DepthCompactor:
    """Assigns requests to lanes by predicted exit depth.

    Also owns THE population depth prior: one EMA (decay ``ema``) over the
    prefill exits actually observed, used to predict the depth of requests
    that arrive without a hint.  (The serving engine used to keep its own
    copy of this EMA with hard-coded constants; there is exactly one now.)
    """

    def __init__(self, n_lanes: int, n_components: int, ema: float = 0.8):
        self.n_lanes = n_lanes
        self.n_components = n_components
        self.ema = ema
        # lane i targets depth band [i * n_c / n_lanes, (i+1) * n_c / n_lanes)
        self.lane_stats = [LaneStats(depth_ema=(i + 0.5) * n_components
                                     / n_lanes)
                           for i in range(n_lanes)]
        self.population_prior = (n_components - 1) / 2

    def predict_depth(self, hint: Optional[float] = None) -> float:
        """Expected exit depth of an incoming request: an explicit hint
        (e.g. an earlier turn's prefill exit) wins; otherwise the running
        population prior over observed prefill exits."""
        return self.population_prior if hint is None else float(hint)

    def observe_prefill_exit(self, depth: float):
        """Warm the population prior with a FIRST prefill exit."""
        self.population_prior = (self.ema * self.population_prior
                                 + (1 - self.ema) * float(depth))

    def assign(self, predicted_depth: float, free_slots: List[int]) -> int:
        """Pick the free lane whose depth estimate is closest."""
        if not free_slots:
            raise ValueError("no free lanes")
        dists = [abs(self.lane_stats[i].depth_ema - predicted_depth)
                 for i in free_slots]
        return free_slots[int(np.argmin(dists))]

    def observe(self, lane: int, exit_depths: np.ndarray,
                segments_skipped: int):
        st = self.lane_stats[lane]
        if len(exit_depths):
            st.depth_ema = (self.ema * st.depth_ema
                            + (1 - self.ema) * float(np.mean(exit_depths)))
        st.steps += 1
        st.skipped_segments += segments_skipped
        st.total_segments += self.n_components - 1

    def skip_rate(self) -> float:
        tot = sum(s.total_segments for s in self.lane_stats)
        if not tot:
            return 0.0
        return sum(s.skipped_segments for s in self.lane_stats) / tot

    def reset_skip_counters(self):
        """Zero the skip accounting without losing the learned depth EMAs
        (scheduler state) — used when the engine resets its metrics after
        jit warm-up so every reported rate covers the same step window."""
        for s in self.lane_stats:
            s.steps = 0
            s.skipped_segments = 0
            s.total_segments = 0
