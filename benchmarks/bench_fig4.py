"""Figure 4 reproduction: α_m(δ) per classifier (near-linearity) and the
confidence histograms over the test set."""
import numpy as np

from benchmarks._shared import trained_cascade
from repro.core.calibration import accuracy_vs_confidence
from repro.core.resnet_trainer import collect_outputs


def run():
    model, report, (_, _, test) = trained_cascade()
    confs, preds, corrects = collect_outputs(model, report.params,
                                             report.state, test)
    rows = []
    for m in range(3):
        grid, alpha = accuracy_vs_confidence(confs[m], corrects[m])
        r = float(np.corrcoef(grid, alpha)[0, 1]) if len(grid) > 10 else np.nan
        rows.append((f"fig4/alpha_linearity_M{m}", 0.0, f"pearson_r={r:.4f}"))
        hist, _ = np.histogram(confs[m], bins=10, range=(0, 1))
        rows.append((f"fig4/conf_hist_M{m}", 0.0,
                     ";".join(str(int(h)) for h in hist)))
    return rows
