"""Optimizers as pure (init, update) pairs over parameter pytrees.

optax is not available in this environment, so we implement the two
optimizers the framework needs:

* ``sgd_momentum`` — the paper trains CI-ResNet with SGD (+momentum 0.9,
  L2 1e-4 folded into the loss per the paper).
* ``adamw`` — for LLM-zoo training steps (the beyond-paper layer).

An Optimizer carries ``init(params) -> state`` and
``update(grads, state, params, step) -> (updates, state)``; the caller applies
``params + updates``.  A trainability mask (pytree of bool, same structure as
params) supports the paper's backtrack training, where phase m freezes
everything but head m.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., Any]  # (grads, state, params, step, mask=None)


def _tree_zeros_like(params):
    return jax.tree_util.tree_map(jnp.zeros_like, params)


def _apply_mask(updates, mask):
    if mask is None:
        return updates
    return jax.tree_util.tree_map(
        lambda u, m: jnp.where(m, u, jnp.zeros_like(u)), updates, mask)


def sgd_momentum(lr: Schedule | float, momentum: float = 0.9,
                 nesterov: bool = False, weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: jnp.asarray(lr))

    def init(params):
        return {"mu": _tree_zeros_like(params)}

    def update(grads, state, params, step, mask=None):
        if weight_decay:
            grads = jax.tree_util.tree_map(
                lambda g, p: g + weight_decay * p, grads, params)
        mu = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g, state["mu"], grads)
        if nesterov:
            upd = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g, mu, grads)
        else:
            upd = mu
        step_lr = lr_fn(step)
        updates = jax.tree_util.tree_map(lambda u: -step_lr * u, upd)
        updates = _apply_mask(updates, mask)
        # masked params should not accumulate momentum either
        if mask is not None:
            mu = jax.tree_util.tree_map(
                lambda m_, msk, old: jnp.where(msk, m_, old),
                mu, mask, state["mu"])
        return updates, {"mu": mu}

    return Optimizer(init=init, update=update)


def adamw(lr: Schedule | float, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: jnp.asarray(lr))

    def init(params):
        return {"m": _tree_zeros_like(params), "v": _tree_zeros_like(params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, step, mask=None):
        count = state["count"] + 1
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g), state["v"], grads)
        c = count.astype(jnp.float32)
        bc1 = 1 - b1 ** c
        bc2 = 1 - b2 ** c
        step_lr = lr_fn(step)

        def upd_leaf(m_, v_, p):
            mhat = m_ / bc1
            vhat = v_ / bc2
            return -step_lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)

        updates = jax.tree_util.tree_map(upd_leaf, m, v, params)
        updates = _apply_mask(updates, mask)
        if mask is not None:
            m = jax.tree_util.tree_map(
                lambda new, msk, old: jnp.where(msk, new, old), m, mask, state["m"])
            v = jax.tree_util.tree_map(
                lambda new, msk, old: jnp.where(msk, new, old), v, mask, state["v"])
        return updates, {"m": m, "v": v, "count": count}

    return Optimizer(init=init, update=update)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gn


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype),
                                  params, updates)
