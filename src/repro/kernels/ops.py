"""Jit'd public wrappers routing model-layer calls to the Pallas kernels.

``interpret`` defaults to True (this container is CPU-only; on a real TPU
deployment set REPRO_KERNEL_INTERPRET=0 to run the compiled kernels).
Wrappers adapt the model's (B, S, H, hd) layouts to the kernels' tiled
layouts and fall back to the jnp reference for shapes the kernels don't
support (e.g. head_dim not a multiple of 8 in interpret tests).
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.confidence import confidence as _confidence
from repro.kernels.decode_attention import decode_attention as _decode_attn
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm

INTERPRET = os.environ.get("REPRO_KERNEL_INTERPRET", "1") != "0"


def softmax_confidence_fused(logits):
    """(..., V) -> (argmax, δ) — Defs 3.2/3.3 via the fused kernel."""
    shape = logits.shape[:-1]
    V = logits.shape[-1]
    flat = logits.reshape(-1, V)
    idx, conf = _confidence(flat, interpret=INTERPRET)
    return idx.reshape(shape), conf.reshape(shape)


def rmsnorm_fused(x, w, eps: float = 1e-5):
    shape = x.shape
    out = _rmsnorm(x.reshape(-1, shape[-1]), w, eps=eps, interpret=INTERPRET)
    return out.reshape(shape)


def flash_attention_bshd(q, k, v, *, causal=True, window=0):
    """Model layout (B, S, H, hd) + (B, S, KV, hd) -> (B, S, H, hd)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _flash(qt, kt, vt, causal=causal, window=window,
                 interpret=INTERPRET)
    return out.transpose(0, 2, 1, 3)


def decode_attention_cache(q, k_cache, v_cache, t, kpos, *, window=0):
    """Model layout: q (B, 1, H, hd); caches (B, W, KV, hd)."""
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    qpk = H // KV
    qg = q[:, 0].reshape(B, KV, qpk, hd)
    kc = k_cache.transpose(0, 2, 1, 3)
    vc = v_cache.transpose(0, 2, 1, 3)
    out = _decode_attn(qg, kc, vc, t, kpos, window=window,
                       interpret=INTERPRET)
    return out.reshape(B, 1, H, hd)
