"""Per-kernel allclose sweeps (interpret=True) against the ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.confidence import confidence
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm

RNG = np.random.default_rng(42)


def _arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,V", [(1, 128), (4, 1000), (16, 8192),
                                 (3, 151), (8, 50304), (2, 131072)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_confidence_kernel(B, V, dtype):
    x = _arr((B, V), dtype, 3.0)
    i1, c1 = confidence(x)
    i2, c2 = ref.ref_confidence(x)
    assert bool(jnp.all(i1 == i2))
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("R,d", [(1, 128), (37, 256), (64, 1024), (8, 8192)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_kernel(R, d, dtype):
    x = _arr((R, d), dtype)
    w = _arr((d,), jnp.float32)
    got = rmsnorm(x, w)
    want = ref.ref_rmsnorm(x, w)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=3e-2 if dtype == jnp.bfloat16 else 1e-5, atol=1e-2)


@pytest.mark.parametrize("B,H,KV,S,hd,window", [
    (2, 4, 2, 256, 64, 0),
    (1, 8, 8, 128, 32, 0),
    (2, 4, 1, 256, 64, 64),
    (1, 2, 2, 512, 128, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel(B, H, KV, S, hd, window, dtype):
    q = _arr((B, H, S, hd), dtype)
    k = _arr((B, KV, S, hd), dtype)
    v = _arr((B, KV, S, hd), dtype)
    got = flash_attention(q, k, v, window=window, tq=64, tk=64)
    want = ref.ref_flash_attention(q, k, v, window=window)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=5e-2 if dtype == jnp.bfloat16 else 1e-4,
        atol=5e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("B,KV,qpk,W,hd,window,t", [
    (2, 2, 4, 128, 64, 0, 100),
    (1, 4, 1, 96, 32, 0, 50),
    (2, 1, 8, 128, 64, 32, 100),
    (1, 8, 2, 640, 128, 0, 639),
])
def test_decode_attention_kernel(B, KV, qpk, W, hd, window, t):
    q = _arr((B, KV, qpk, hd))
    kc = _arr((B, KV, W, hd))
    vc = _arr((B, KV, W, hd))
    kpos = jnp.asarray(np.where(np.arange(W) <= t, np.arange(W), -1),
                       jnp.int32)
    got = decode_attention(q, kc, vc, t, kpos, window=window, tk=64)
    want = ref.ref_decode_attention(
        q.reshape(B, KV * qpk, hd), kc.transpose(0, 2, 1, 3),
        vc.transpose(0, 2, 1, 3), t, kpos, window=window)
    np.testing.assert_allclose(np.asarray(got.reshape(B, KV * qpk, hd)),
                               np.asarray(want), rtol=1e-4, atol=1e-5)


def test_decode_attention_ring_wraparound():
    """Ring-buffer semantics: slots hold non-contiguous absolute positions."""
    B, KV, qpk, W, hd = 1, 1, 1, 64, 32
    q = _arr((B, KV, qpk, hd))
    kc = _arr((B, KV, W, hd))
    vc = _arr((B, KV, W, hd))
    t = 100
    # slot j holds position: largest p <= t with p % W == j
    s = np.arange(W)
    kpos = jnp.asarray(t - ((t - s) % W), jnp.int32)
    got = decode_attention(q, kc, vc, t, kpos, window=32, tk=32)
    want = ref.ref_decode_attention(
        q.reshape(B, KV * qpk, hd), kc.transpose(0, 2, 1, 3),
        vc.transpose(0, 2, 1, 3), t, kpos, window=32)
    np.testing.assert_allclose(np.asarray(got.reshape(B, 1, hd)),
                               np.asarray(want), rtol=1e-4, atol=1e-5)
