"""llama-3.2-vision-90b — cross-attn image layers [hf:meta-llama/Llama-3.2-11B-Vision].

The ViT vision encoder + projector is a STUB per the assignment: ``input_specs``
provides precomputed image patch embeddings (batch, n_image_tokens, d_model).
Every 5th decoder layer carries gated cross-attention to the image tokens
(20 cross-attn layers out of 100, mirroring the 11B card's 1:5 ratio).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_every=5,
    n_image_tokens=1600,       # 1 tile x (40x40) patches, projector output
    rope_theta=5e5,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
))
