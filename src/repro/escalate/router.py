"""The per-stage answer-or-defer rule and its online agreement telemetry.

The defer decision is IDK-style (Wang et al., 2017): only the FINAL
component of a stage's intra-model cascade may abstain.  Tokens an
earlier component answered already beat their intra threshold — they
stand.  A token the final component answered is additionally gated by
the stage's escalation threshold: confidence below it defers the whole
request (from that token on) to the next stage.

The router also measures ``stage_agree`` — P(a rejected stage-s answer
equals the next stage's regeneration at the same context) — which is the
chaining factor :func:`repro.autotune.solver.compose_escalation` needs to
express tier-level agreement through stage-0's self-agreement proxy.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.configs.base import ModelConfig


class EscalationRouter:
    """Holds the live escalation thresholds (one per non-final stage) and
    the defer rule.  Thresholds are mutable data — the tier controller
    re-solves and pushes them the same way intra-model thresholds move."""

    def __init__(self, stage_cfgs: Sequence[ModelConfig]):
        if not stage_cfgs:
            raise ValueError("need at least one stage")
        self.stage_cfgs = list(stage_cfgs)
        for s, cfg in enumerate(self.stage_cfgs[:-1]):
            esc = cfg.escalation
            if esc.confidence and esc.confidence != cfg.cascade.confidence:
                # the defer decision reuses the confidence the decision
                # scan computed for the answering token; the engine does
                # not retain logits, so a different measure is unservable
                raise ValueError(
                    f"stage {s} escalation.confidence "
                    f"{esc.confidence!r} != its cascade.confidence "
                    f"{cfg.cascade.confidence!r}; the defer decision "
                    "reuses the decision-time confidence — leave it \"\" "
                    "to inherit")
        self.thresholds: List[float] = [
            float(cfg.escalation.threshold)
            for cfg in self.stage_cfgs[:-1]]
        # online stage-agreement telemetry: rejected stage-s token vs the
        # next stage's first regenerated token at the same context
        self._agree_n = 0
        self._agree_hits = 0

    # -- defer rule ------------------------------------------------------
    def set_threshold(self, stage: int, threshold: float):
        if not 0 <= stage < len(self.thresholds):
            raise IndexError(
                f"stage {stage} has no escalation threshold "
                f"({len(self.thresholds)} non-final stages)")
        self.thresholds[stage] = float(threshold)

    def should_defer(self, stage: int, exit_depth: int,
                     conf: float) -> bool:
        """Does this (answered) token abstain?  Only final-component
        answers may: 0.0 never defers (confidences are >= 0), the 1.1
        sentinel always defers final-component answers."""
        if stage >= len(self.thresholds):
            return False                   # last stage is the authority
        n_m = self.stage_cfgs[stage].cascade.n_components
        return (exit_depth == n_m - 1
                and float(conf) < self.thresholds[stage])

    def first_defer(self, stage: int, exit_depths: Sequence[int],
                    confs: Sequence[float], start: int = 0
                    ) -> Optional[int]:
        """Index of the first deferring token at/after ``start`` in a
        request's (exit_depth, conf) streams, or None."""
        for i in range(start, len(exit_depths)):
            if self.should_defer(stage, exit_depths[i], confs[i]):
                return i
        return None

    # -- stage-agreement telemetry ---------------------------------------
    def observe_regeneration(self, rejected_token: int,
                             regenerated_token: int):
        """One rejected token got re-answered by the next stage at the
        same context: record whether the draft had it right anyway."""
        self._agree_n += 1
        self._agree_hits += int(
            int(rejected_token) == int(regenerated_token))

    def stage_agree(self, prior: float = 1.0,
                    min_observations: int = 1) -> float:
        """Measured P(rejected draft answer == next stage's answer), or
        ``prior`` until ``min_observations`` rejections have been
        scored."""
        if self._agree_n < max(1, int(min_observations)):
            return float(prior)
        return self._agree_hits / self._agree_n

    def stats(self) -> dict:
        return {
            "thresholds": list(self.thresholds),
            "regenerations_scored": self._agree_n,
            "regenerations_agreed": self._agree_hits,
            "stage_agree": (self._agree_hits / self._agree_n
                            if self._agree_n else None),
        }
