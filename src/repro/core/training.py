"""Backtrack training — Algorithm 2 of the paper — plus the joint-loss
baseline (BranchyNet-style) used for comparison and for the dry-run graphs.

BT(M, T, n_e):
  1. optimize Θ_conv ∪ θ_fc_{n_m−1} with L(out_{n_m−1}) for 1.25·n_e epochs
  2. for m = 0 … n_m−2: optimize θ_fc_m with L(out_m) for n_e epochs

Phases are realized with *trainability masks* over the parameter pytree fed
to the optimizer (repro.optim), so one jitted train_step serves every phase:
the mask zeroes updates (and momentum writes) of frozen leaves.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.utils import path_str


@dataclasses.dataclass(frozen=True)
class Phase:
    name: str
    loss_head: int          # which exit's loss to optimize (-1 = last)
    epochs: float           # multiplier on n_e
    train_backbone: bool
    train_heads: Tuple[int, ...]  # exit-head indices receiving updates


def backtrack_training_plan(n_components: int) -> List[Phase]:
    """The paper's Algorithm 2 as a phase list."""
    phases = [Phase("backbone+last", loss_head=n_components - 1,
                    epochs=1.25, train_backbone=True, train_heads=())]
    for m in range(n_components - 1):
        phases.append(Phase(f"head{m}", loss_head=m, epochs=1.0,
                            train_backbone=False, train_heads=(m,)))
    return phases


def _is_exit_leaf(path: str) -> Tuple[bool, int]:
    parts = path.split("/")
    if "exits" in parts:
        i = parts.index("exits")
        return True, int(parts[i + 1])
    return False, -1


def _is_final_head_leaf(path: str) -> bool:
    return path.split("/")[0] in ("final_norm", "lm_head", "head_final")


def trainability_mask(params, phase: Phase):
    """Bool pytree: True where the optimizer may update in this phase."""
    def leaf_mask(path, leaf):
        p = path_str(path)
        is_exit, idx = _is_exit_leaf(p)
        if is_exit:
            return jnp.asarray(idx in phase.train_heads)
        if _is_final_head_leaf(p):
            # the final classifier trains together with the backbone (line 1)
            return jnp.asarray(phase.train_backbone)
        return jnp.asarray(phase.train_backbone)
    return jax.tree_util.tree_map_with_path(leaf_mask, params)


def cross_entropy(logits, labels):
    """Mean CE.  logits (..., C); labels integer (...)."""
    logz = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logz, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def l2_loss(params, coef: float):
    """The paper regularizes with an L2 loss, coefficient 1e-4."""
    if not coef:
        return jnp.zeros((), jnp.float32)
    acc = jnp.zeros((), jnp.float32)
    for leaf in jax.tree_util.tree_leaves(params):
        if jnp.issubdtype(leaf.dtype, jnp.floating) and leaf.ndim >= 2:
            acc = acc + jnp.sum(jnp.square(leaf.astype(jnp.float32)))
    return coef * acc


def cascade_loss(exit_logits: Sequence[jnp.ndarray], labels, mode: str,
                 head: int = -1, joint_weights: Sequence[float] = (),
                 aux: jnp.ndarray | None = None,
                 aux_coef: float = 0.0):
    """Loss over cascade exits.

    mode "single": L(out_head) — used by every BT phase (Algorithm 2).
    mode "joint":  Σ_m w_m · L(out_m) — the BranchyNet baseline the paper
                   contrasts with, and the dry-run's representative graph.
    """
    def _ce(lg, y):
        # intermediate exits may be position-strided (cascade.exit_loss_stride)
        if lg.ndim == y.ndim + 1 and lg.shape[-2] != y.shape[-1]:
            stride = y.shape[-1] // lg.shape[-2]
            y = y[..., ::stride]
        return cross_entropy(lg, y)

    if mode == "single":
        loss = _ce(exit_logits[head], labels)
    elif mode == "joint":
        n = len(exit_logits)
        w = list(joint_weights) or [1.0] * n
        loss = sum(wi * _ce(lg, labels)
                   for wi, lg in zip(w, exit_logits)) / sum(w)
    else:
        raise ValueError(mode)
    if aux is not None and aux_coef:
        loss = loss + aux_coef * aux
    return loss
