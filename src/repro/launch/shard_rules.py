"""Name-based sharding rules: parameter / optimizer / cache / batch /
decode-state pytrees -> PartitionSpec trees for the production mesh.

Tensor-parallel layout (megatron-style): column-parallel projections shard
their output dim over ``model``; row-parallel shard their input dim (XLA
inserts the all-reduce after the row-parallel matmul).  MoE experts shard the
expert dim when divisible (expert parallelism), else fall back to
tensor-parallel inside each expert.  Vocab-sharded embedding/unembedding when
the vocab divides the axis.  The batch dim shards over (pod, data); the
batch-1 long-context shape shards the KV-cache *sequence* dim over data
instead (sequence-parallel decode).

Every divisibility decision funnels through ``_axis_if`` so a config change
can never produce an invalid sharding — it degrades to replication.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import axis_size, batch_axes, divisible
from repro.utils import path_str

COLUMN = {"wq", "wk", "wv", "w_up", "w_gate", "up_proj", "w_in", "in_proj",
          "head", "lm_head", "enh_w1"}
ROW = {"wo", "w_down", "down_proj", "out_proj", "w_dn", "enh_w2"}


def _axis_if(dim: int, mesh, axis: str) -> Optional[str]:
    return axis if divisible(dim, axis_size(mesh, axis)) else None


def _spec(ndim: int, **placed) -> P:
    """Build a PartitionSpec placing axes at (possibly negative) dims."""
    entries = [None] * ndim
    for pos, ax in placed.items():
        if ax is not None:
            # canonicalize 1-tuples to the bare axis name (newer jax does
            # this inside PartitionSpec; 0.4.37 keeps the tuple as-is)
            if isinstance(ax, tuple) and len(ax) == 1:
                ax = ax[0]
            entries[int(pos)] = ax
    return P(*entries)


def _add_fsdp(spec: P, shape, mesh) -> P:
    """ZeRO/FSDP: additionally shard the first free divisible dim over
    'data'.  GSPMD materializes the per-layer all-gather; optimizer state
    (same spec) stays fully sharded — this is what lets 90B-param AdamW fit
    16 GiB/chip."""
    dsz = axis_size(mesh, "data")
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i, (dim, cur) in enumerate(zip(shape, entries)):
        if cur is None and divisible(dim, dsz):
            entries[i] = "data"
            return P(*entries)
    return spec


def param_spec(params, cfg, mesh, fsdp: bool = True, mode: str = "default"):
    """PartitionSpec tree matching a CascadeModel (or optimizer) pytree.

    mode="default": megatron TP over 'model' + ZeRO/FSDP 'data' placement on
    the first free divisible dim (training layout — optimizer state must be
    fully sharded; the per-layer weight all-gather amortizes over a large
    fwd+bwd).

    mode="serve2d": inference layout — weights shard over the COMBINED
    ('model','data') axes on their TP dim, so no weight ever needs gathering;
    the row-parallel output all-reduce moves to activations, which at decode
    are ~1 token and orders of magnitude smaller than the weights (§Perf H1).
    Decode-only: at prefill the (B,S,d) activations would replicate over
    'data' and dwarf the weight traffic.

    mode="serve1d": prefill inference layout — megatron TP over 'model',
    weights REPLICATED over 'data' (no FSDP): inference has no optimizer
    state, so when params/16 fit HBM the per-layer FSDP all-gather is pure
    waste (§Perf H3).
    """
    combined = ("model", "data")
    comb_sz = axis_size(mesh, combined)

    def rule(path, leaf):
        ndim = np.ndim(leaf)
        shape = np.shape(leaf)
        name = None
        for part in reversed(path):
            key = getattr(part, "key", None)
            if isinstance(key, str):
                name = key
                break
        if name is None or ndim == 0:
            return P()
        p = path_str(path)
        if name == "embed":
            spec = _spec(ndim, **{str(ndim - 2): _axis_if(shape[-2], mesh,
                                                          "model")})
        elif name == "pos_embed":
            spec = P()
        elif "moe" in p and name in ("w_up", "w_gate", "w_down"):
            E = shape[-3]
            ff_dim = ndim - 1 if name != "w_down" else ndim - 2
            if divisible(E, axis_size(mesh, "model")):
                if (mode == "serve2d"
                        and divisible(shape[ff_dim], axis_size(mesh, "data"))):
                    # expert-parallel over model + intra-expert ff over data:
                    # fully sharded, zero weight gathers (§Perf H1)
                    return _spec(ndim, **{str(ndim - 3): "model",
                                          str(ff_dim): "data"})
                spec = _spec(ndim, **{str(ndim - 3): "model"})
            else:
                if mode == "serve2d" and divisible(shape[ff_dim], comb_sz):
                    return _spec(ndim, **{str(ff_dim): combined})
                spec = _spec(ndim, **{str(ff_dim): _axis_if(
                    shape[ff_dim], mesh, "model")})
        elif name in COLUMN and ndim >= 2:
            if mode == "serve2d" and divisible(shape[-1], comb_sz):
                return _spec(ndim, **{str(ndim - 1): combined})
            spec = _spec(ndim, **{str(ndim - 1): _axis_if(shape[-1], mesh,
                                                          "model")})
        elif name in ROW and ndim >= 2:
            if mode == "serve2d" and divisible(shape[-2], comb_sz):
                return _spec(ndim, **{str(ndim - 2): combined})
            spec = _spec(ndim, **{str(ndim - 2): _axis_if(shape[-2], mesh,
                                                          "model")})
        else:
            spec = P()
        # serve2d never places 'data' on a dim it can't fully own — a
        # data-sharded contraction dim is exactly what made GSPMD emit the
        # giant weight all-gathers the mode exists to remove.
        if fsdp and mode not in ("serve2d", "serve1d") and ndim >= 2:
            spec = _add_fsdp(spec, shape, mesh)
        return spec
    return jax.tree_util.tree_map_with_path(rule, params)


def cache_spec(cache, cfg, mesh, batch: int):
    """KV/state cache sharding.  batch > 1: shard batch over (pod,data);
    batch == 1 (long-context): shard the KV sequence dim over (pod,data)
    — sequence-parallel decode — and replicate recurrent states.

    Paged layout (detected from the per-slot ``(B, W)`` kpos ring): the
    shared k/v block stores are ``(L, num_blocks, bs, kv, hd)`` with NO
    batch dim — blocks are fungible across slots — so the physical block
    dim shards over (pod, data) instead (block-parallel store; GSPMD
    routes each table-indexed gather to the owning shard), and the kpos
    ring batch-shards like any per-slot leaf."""
    dp = batch_axes(mesh)
    dp_sz = axis_size(mesh, dp)
    batch_ok = divisible(batch, dp_sz)
    dp_ax = dp if batch_ok else None
    paged = (isinstance(cache, dict)
             and np.ndim(cache.get("kpos")) == 2)

    def rule(path, leaf):
        ndim = np.ndim(leaf)
        shape = np.shape(leaf)
        name = None
        for part in reversed(path):
            key = getattr(part, "key", None)
            if isinstance(key, str):
                name = key
                break
        if name == "kpos":
            if paged and ndim == 2:                # per-slot (B, W) ring
                return _spec(ndim, **{"0": dp_ax})
            return P()
        if ndim <= 1:
            return P()
        if name in ("k", "v") and ndim == 5:
            if paged:                              # (L, NB, bs, kv, hd)
                return _spec(ndim, **{"1": dp if divisible(shape[1], dp_sz)
                                      else None})
            if batch_ok:                           # (L, B, W, kv, hd)
                return _spec(ndim, **{"1": dp_ax})
            # sequence-parallel: shard the slot dim
            return _spec(ndim, **{"2": dp if divisible(shape[2], dp_sz)
                                  else None})
        if name == "conv" and ndim == 4:           # (L, B, W-1, ch)
            return _spec(ndim, **{"1": dp_ax})
        if name == "state" and ndim == 5:          # ssm (L, B, h, p, n)
            return _spec(ndim, **{"1": dp_ax})
        if name == "C" and ndim == 5:              # mlstm (L, B, h, p, p)
            return _spec(ndim, **{"1": dp_ax})
        if name == "n" and ndim == 4:              # mlstm (L, B, h, p)
            return _spec(ndim, **{"1": dp_ax})
        if name == "m" and ndim == 3:              # mlstm (L, B, h)
            return _spec(ndim, **{"1": dp_ax})
        if name in ("c", "n", "m", "h") and ndim == 3:  # slstm (L, B, d)
            return _spec(ndim, **{"1": dp_ax})
        return P()
    return jax.tree_util.tree_map_with_path(rule, cache)


def decode_state_spec(state, cfg, mesh, batch: int):
    """Sharding for the serve step's carried DecodeState pytree.

    Per-sequence leaves (``active``, ``ema_conf``: (B,), and the stateful
    measure carry ``policy``: (n_components, B)) shard their batch dim over
    (pod, data) exactly like the token batch; the scalar cursor ``t`` and
    the per-segment counters ``segments_run`` replicate.  The autotune
    riders — the live ``thresholds`` vector and every batch-free
    :class:`~repro.autotune.telemetry.ExitTelemetry` counter (histograms,
    exit/MAC/step counters) — replicate too: they are global accumulators,
    and GSPMD folds the batch-sharded scatter-adds into them with the
    appropriate reductions.  Divisibility degrades to replication,
    mirroring every other rule here.
    """
    dp = batch_axes(mesh)
    dp_ax = dp if divisible(batch, axis_size(mesh, dp)) else None

    def rule(path, leaf):
        ndim = np.ndim(leaf)
        name = None
        for part in reversed(path):
            key = getattr(part, "name", None) or getattr(part, "key", None)
            if isinstance(key, str):
                name = key
                break
        if ndim == 0 or name in ("t", "segments_run"):
            return P()
        if name in ("active", "ema_conf"):
            return _spec(ndim, **{"0": dp_ax})
        if name == "policy":          # (n_components, B, ...)
            return _spec(ndim, **{"1": dp_ax})
        if name == "block_tables":    # paged cache (n_components, B, nblk)
            return _spec(ndim, **{"1": dp_ax})
        # "thresholds" and the telemetry counters fall through: replicated
        return P()
    return jax.tree_util.tree_map_with_path(rule, state)


def decode_loop_in_specs(params, cache, state, cfg, mesh, batch: int):
    """Input PartitionSpecs for ``launch.steps.make_decode_loop_step``'s
    ``(params, token, cache, state, remaining, extra)`` signature — the whole
    while_loop carry sharded by the existing rules: weights via
    :func:`param_spec` (serve1d inference layout), the KV/state cache via
    :func:`cache_spec`, the carried DecodeState via
    :func:`decode_state_spec`, and the (B, 1) token / (B,) remaining-budget
    vectors batch-sharded like any token batch.  ``extra`` is left
    unconstrained (None)."""
    return (param_spec(params, cfg, mesh, mode="serve1d"),
            batch_spec(cfg, mesh, batch, 2),
            cache_spec(cache, cfg, mesh, batch),
            decode_state_spec(state, cfg, mesh, batch),
            batch_spec(cfg, mesh, batch, 1),
            None)


def batch_spec(cfg, mesh, batch: int, ndim: int) -> P:
    dp = batch_axes(mesh)
    if divisible(batch, axis_size(mesh, dp)):
        return _spec(ndim, **{"0": dp})
    return P()


def to_shardings(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
