"""Device-resident decode runtime: multi-token serving without per-token
host round-trips.

The host-runtime engine (`serving/engine.py`) dispatches ONE jitted decode
step per generated token and immediately syncs the result to host
(``np.asarray(tok)``), so at small lane batches the per-call dispatch +
sync overhead swamps exactly the compute that ``cond_batch`` segment
skipping saves.  :class:`DeviceDecodeLoop` closes that gap: it jits a
``lax.while_loop`` over ``(DecodeState, cache, token, output buffers)``
(built by :func:`repro.launch.steps.make_decode_loop_step`) and decodes up
to K tokens entirely on device — tokens, exit indices, confidences and the
per-step live mask land in preallocated ``(K, B)`` device buffers, and the
host syncs once per chunk instead of once per token.

Because each loop iteration is one :class:`~repro.core.exec.StagedExecutor`
step, everything the staged executor does carries over unchanged inside the
loop: cond_batch segment skipping, cohort-split skip predicates
(``cascade.n_cohorts``) in either cohort layout (the cohort-major hot path
or the legacy copy ablation — ``cascade.cohort_layout``), stateful measures
(patience streaks ride in the carried ``DecodeState.policy``), and the
per-segment execution counters.  With ``cfg.use_kernels`` the kernel fast
path also runs *inside* the while_loop carry: the per-slot
``DecodeState.active`` mask reaches the exit-masked decode-attention kernel
every iteration (drained slots stop paying attention FLOPs mid-chunk), and
each component's exit decision + DecodeState update (patience streaks,
confidence EMA) is one fused exit-update kernel over the exit logits.
The loop ends early once every slot has either spent its token budget or
hit the cache limit, mirroring the host engine's per-token finish rule —
which is what keeps host- and device-runtime token streams bit-identical
(pinned by ``tests/test_runtime.py``).  The one sanctioned divergence is
admission timing: requests still QUEUED when a chunk starts join only at
the next chunk boundary (the engine admits between dispatches), so under
over-capacity load a lane's re-prefill point — and with it the affected
sequences — can differ from the host runtime's per-token admission.

Multi-device lanes: pass a ``mesh`` and the whole loop carry is sharded by
the existing rules in :mod:`repro.launch.shard_rules`
(:func:`~repro.launch.shard_rules.decode_loop_in_specs` — weights serve1d,
cache via ``cache_spec``, DecodeState via ``decode_state_spec``, token /
budget vectors batch-sharded).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.shard_rules import decode_loop_in_specs, to_shardings
from repro.launch.steps import make_decode_loop_step
from repro.utils import get_logger

log = get_logger("serving.runtime")


def kernel_provenance(cfg) -> dict:
    """The kernel execution backend this config actually runs — recorded
    in every serving bench row so a speedup number can never be read
    without knowing whether it was measured through the Pallas interpreter
    (CPU CI: advisory) or the compiled Mosaic path (gated strictly)."""
    from repro.kernels.backend import resolve_interpret
    interpret = resolve_interpret(cfg.kernel_interpret)
    return {
        "kernel_backend": "interpret" if interpret else "compiled",
        "kernel_platform": jax.default_backend(),
    }


@dataclasses.dataclass
class DecodeChunk:
    """Host view of one device-loop dispatch, trimmed to the steps that ran.

    ``tokens`` / ``exits`` / ``confs`` / ``live`` are (n_steps, B); row i of
    ``live`` marks the slots that were still generating when step i's token
    was produced (a slot's valid outputs are exactly its True rows).
    ``seconds`` is the host-measured wall-clock of the dispatch including
    the single per-chunk sync; ``t_host`` is the ``perf_counter`` stamp at
    dispatch start (so the flight recorder can place the chunk's slice on
    a wall-clock timeline without adding any sync of its own); ``compiled``
    marks the warm-up call that paid jit compilation (callers should
    report its time as compile cost, not decode cost).
    """

    tokens: np.ndarray
    exits: np.ndarray
    confs: np.ndarray
    live: np.ndarray
    n_steps: int
    remaining: np.ndarray
    seconds: float
    compiled: bool
    t_host: float = 0.0


class DeviceDecodeLoop:
    """Jitted K-token ``lax.while_loop`` decode over the staged executor.

    One instance per (config, lane shape): the loop program is compiled
    once and reused by every lane, since all lanes share
    ``(lane_batch, cache_len)``.  ``run_chunk`` is the whole public
    surface — feed it the lane's continuation token, cache, carried
    DecodeState and per-slot remaining-token budget; get back a
    :class:`DecodeChunk` plus the new (device-resident, donated-in)
    cache and state.

    With ``mesh`` set, inputs are constrained to the shard_rules layout so
    lanes run multi-device; the loop carry never leaves the mesh.
    """

    def __init__(self, model, cfg, chunk: int = 8, cache_len: int = 256,
                 mesh=None):
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        self.cfg = cfg
        self.chunk = int(chunk)
        self.cache_len = int(cache_len)
        self.mesh = mesh
        # install tuned tiles BEFORE the loop program traces: tiles are
        # static kernel params, so installing later would force a retrace;
        # installing here keeps _cache_size() == 1 for the lane lifetime
        kt = getattr(cfg, "kernel_tune", None)
        if kt is not None and kt.enabled:
            from repro.kernels.autotune import ensure_tuned
            ensure_tuned(cfg)
        self._fn = make_decode_loop_step(model, cfg, self.chunk,
                                         self.cache_len)
        self._jitted = None
        self.compile_seconds = 0.0
        self._warm = False

    # ------------------------------------------------------------------
    def _build(self, params, cache, state, batch: int):
        # cache + state are donated: the loop is the only consumer and the
        # caller always adopts the returned buffers (in-place carry keeps
        # the chunk wall-clock honest, exactly like the host engine's step)
        if self.mesh is None:
            return jax.jit(self._fn, donate_argnums=(2, 3))
        specs = decode_loop_in_specs(params, cache, state, self.cfg,
                                     self.mesh, batch)
        shardings = tuple(
            None if s is None else to_shardings(self.mesh, s)
            for s in specs)
        return jax.jit(self._fn, in_shardings=shardings,
                       donate_argnums=(2, 3))

    # ------------------------------------------------------------------
    def run_chunk(self, params, token, cache, state, remaining, extra=None):
        """Decode up to ``chunk`` tokens for one lane on device.

        token: (B, 1) int32 continuation token per slot; remaining: (B,)
        int32 tokens each slot may still generate (0 = finished slot).
        ``state.active`` must already mask finished slots.  Returns
        ``(DecodeChunk, new_cache, new_state)``; the passed cache/state are
        donated and must not be reused.
        """
        token = jnp.asarray(np.asarray(token, np.int32))
        remaining = jnp.asarray(np.asarray(remaining, np.int32))
        if self._jitted is None:
            self._jitted = self._build(params, cache, state, token.shape[0])
        t0 = time.perf_counter()
        (toks, exits, confs, live, n_steps, cache, state,
         rem) = self._jitted(params, token, cache, state, remaining, extra)
        # the ONE host sync per chunk: a single batched device_get of the
        # small (K, B) buffers + counters (cache/state stay on device)
        n, toks, exits, confs, live, rem = jax.device_get(
            (n_steps, toks, exits, confs, live, rem))
        n = int(n)
        toks, exits, confs, live = (toks[:n], exits[:n], confs[:n], live[:n])
        seconds = time.perf_counter() - t0
        compiled = not self._warm
        if compiled:
            self._warm = True
            self.compile_seconds += seconds
            log.debug("decode loop compiled in %.3fs (chunk=%d)",
                      seconds, self.chunk)
        return (DecodeChunk(tokens=toks, exits=exits, confs=confs,
                            live=live, n_steps=n, remaining=rem,
                            seconds=seconds, compiled=compiled,
                            t_host=t0),
                cache, state)
