"""Synthetic language-model token pipeline with device-sharded batches.

For the LLM-zoo layer we need a deterministic, offline token stream whose
next-token distribution has learnable structure *and* per-position difficulty
variation (so cascade exits are exercised end-to-end).  We generate tokens
from a small random Markov chain over the vocabulary: runs of high-probability
transitions (easy positions) interleaved with uniform-noise segments (hard
positions).

``shard_batch`` places a host batch onto a mesh with a NamedSharding — the
standard multi-host pattern (each host would feed its slice; single-host here).
"""
from __future__ import annotations

from typing import Iterator, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class SyntheticLMStream:
    """Markov-chain token stream: ``next = argmax-ish(P[cur])`` with noise."""

    def __init__(self, vocab_size: int, seq_len: int, batch_size: int,
                 branch: int = 4, easy_frac: float = 0.7, seed: int = 0):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.easy_frac = easy_frac
        rng = np.random.default_rng(seed)
        # sparse transition table: each token has `branch` likely successors,
        # chosen with a skewed distribution so easy positions are genuinely
        # predictable (the per-position difficulty the cascade exploits)
        self.next_tok = rng.integers(
            0, vocab_size, size=(vocab_size, branch)).astype(np.int64)
        p = 0.15 ** np.arange(branch)   # [0.85, 0.13, 0.02, …] after norm
        self.branch_p = p / p.sum()
        self._rng = rng

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        return self

    def __next__(self) -> Tuple[np.ndarray, np.ndarray]:
        r = self._rng
        b, s, v = self.batch_size, self.seq_len, self.vocab_size
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = r.integers(0, v, b)
        easy = r.random((b, s)) < self.easy_frac
        choice = r.choice(self.next_tok.shape[1], size=(b, s),
                          p=self.branch_p)
        rand_tok = r.integers(0, v, (b, s))
        for t in range(s):
            markov = self.next_tok[toks[:, t], choice[:, t]]
            toks[:, t + 1] = np.where(easy[:, t], markov, rand_tok[:, t])
        return toks[:, :-1], toks[:, 1:]  # inputs, labels


def shard_batch(batch, mesh: Mesh, batch_axes=("data",)):
    """Place a host-side batch on the mesh, batch dim sharded over batch_axes."""
    spec = P(batch_axes)
    return jax.tree_util.tree_map(
        lambda x: jax.device_put(
            x, NamedSharding(mesh, P(batch_axes, *([None] * (x.ndim - 1))))),
        batch)
