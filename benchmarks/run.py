"""Benchmark driver — one benchmark per paper table/figure plus the
beyond-paper LLM-cascade and kernel benches.

Prints ``name,us_per_call,derived`` CSV (and tees a copy to
results/bench.csv when results/ exists).  Whenever the llm_cascade bench
runs its host-vs-device serving comparison, the machine-readable summary
(wall-clock µs/token per runtime, device_speedup, realized skip rate,
opportunity rate, MAC speedup, compile seconds) is persisted to
``BENCH_serving.json`` at the repo root so the serving perf trajectory is
tracked across PRs.

    python benchmarks/run.py [--quick] [--only llm_cascade,fig3]

``--quick`` shrinks workloads (CI smoke lanes); ``--only`` selects benches.
"""
import argparse
import inspect
import json
import os
import sys
import traceback

# runnable as `python benchmarks/run.py` from the repo root
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

# BENCH_serving.json summary schema: bump when a section's shape changes
# incompatibly.  The checker warns (not fails) on versions it does not
# know, so an old checker can still gate what it understands.
SCHEMA_VERSION = 2


def _run_meta() -> dict:
    """Run provenance stamped into the summary: which stack measured it."""
    import platform

    import jax
    return {
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "python": platform.python_version(),
        "platform": platform.platform(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller workloads (CI smoke lanes)")
    ap.add_argument("--only", default="",
                    help="comma-separated bench names to run")
    args = ap.parse_args()

    from benchmarks import (bench_table2, bench_fig3, bench_fig4,
                            bench_llm_cascade, bench_kernels,
                            bench_ablation, bench_autotune, bench_fleet,
                            bench_obs)
    mods = [("table2", bench_table2), ("fig3", bench_fig3),
            ("fig4", bench_fig4), ("ablation", bench_ablation),
            ("llm_cascade", bench_llm_cascade), ("kernels", bench_kernels),
            ("autotune", bench_autotune), ("fleet", bench_fleet),
            ("obs", bench_obs)]
    if args.only:
        wanted = {w.strip() for w in args.only.split(",") if w.strip()}
        unknown = wanted - {n for n, _ in mods}
        if unknown:
            sys.exit(f"unknown bench(es): {sorted(unknown)}")
        mods = [(n, m) for n, m in mods if n in wanted]
    lines = ["name,us_per_call,derived"]
    failed = False
    for name, mod in mods:
        try:
            kwargs = {}
            if "quick" in inspect.signature(mod.run).parameters:
                kwargs["quick"] = args.quick
            for row_name, us, derived in mod.run(**kwargs):
                lines.append(f"{row_name},{us:.1f},{derived}")
        except Exception as e:
            failed = True
            lines.append(f"{name}/ERROR,0.0,{type(e).__name__}:{e}")
            traceback.print_exc()
    out = "\n".join(lines)
    print(out)
    if os.path.isdir("results"):
        with open("results/bench.csv", "w") as f:
            f.write(out + "\n")
    summary = getattr(bench_llm_cascade, "LAST_SERVING_SUMMARY", None)
    autotune = getattr(bench_autotune, "LAST_AUTOTUNE_SUMMARY", None)
    fleet = getattr(bench_fleet, "LAST_FLEET_SUMMARY", None)
    kernels = getattr(bench_kernels, "LAST_KERNELS_SUMMARY", None)
    obs = getattr(bench_obs, "LAST_OBS_SUMMARY", None)
    sections = {"autotune": autotune, "fleet": fleet, "kernels": kernels,
                "obs": obs}
    if summary is not None or any(v is not None for v in sections.values()):
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        path = os.path.join(root, "BENCH_serving.json")
        # partial runs (--only) update their section and keep the rest
        data = {}
        if os.path.exists(path):
            with open(path) as f:
                data = json.load(f)
        if summary is not None:
            keep = {k: data.get(k) for k in sections}
            data = dict(summary)
            for k, v in keep.items():
                if v is not None:
                    data[k] = v
        for k, v in sections.items():
            if v is not None:
                data[k] = v
        data["schema_version"] = SCHEMA_VERSION
        data["meta"] = _run_meta()
        with open(path, "w") as f:
            json.dump(data, f, indent=2)
            f.write("\n")
        print(f"# serving summary -> {path}", file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
